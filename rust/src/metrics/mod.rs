//! Timing, robust statistics, and CSV logging for the benchmark protocol.
//!
//! Mirrors the paper's measurement rules (§5): per-step wall-clock with
//! explicit synchronization, medians across repeats, a single CSV that all
//! tables/figures are rendered from.

use std::fmt::Write as _;
use std::path::Path;
use std::time::Instant;

/// Simple wall-clock stopwatch (monotonic).
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed milliseconds.
    pub fn ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Robust summary of a sample of measurements.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub p10: f64,
    pub p90: f64,
    pub std: f64,
}

/// Summarize (empty input gives all zeros).
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = s.len();
    let mean = s.iter().sum::<f64>() / n as f64;
    let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Summary {
        n,
        mean,
        median: percentile_sorted(&s, 50.0),
        min: s[0],
        max: s[n - 1],
        p10: percentile_sorted(&s, 10.0),
        p90: percentile_sorted(&s, 90.0),
        std: var.sqrt(),
    }
}

/// Linear-interpolated percentile of an already sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    let n = sorted.len();
    if n == 0 {
        return 0.0;
    }
    if n == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Median of raw (unsorted) values.
pub fn median(xs: &[f64]) -> f64 {
    summarize(xs).median
}

/// One benchmark row — the schema of `results/bench.csv`, mirroring the
/// paper's `scripts/bench_grid.py` output.
#[derive(Clone, Debug)]
pub struct BenchRow {
    pub dataset: String,
    pub variant: String, // "dgl" | "fsa"
    /// Sampling depth (= `fanout` segment count).
    pub hops: u32,
    /// Canonical fanout label, e.g. "15x10" or "10x5x5".
    pub fanout: String,
    pub batch: u32,
    pub amp: bool,
    pub repeat_seed: u64,
    pub steps: u32,
    /// Median per-step wall clock (ms): forward+backward+optimizer,
    /// synchronized (paper's primary metric).
    pub step_ms: f64,
    /// Host-side sampling share of the step (baseline only; 0 for fsa).
    pub sample_ms: f64,
    /// Upload (literal creation + transfer) share of the step.
    pub upload_ms: f64,
    /// Device execute share of the step.
    pub execute_ms: f64,
    /// Raw sampled (seed, neighbor) pairs per second.
    pub pairs_per_s: f64,
    /// Seeds (nodes) per second.
    pub nodes_per_s: f64,
    /// Peak transient memory per step, bytes (meter + analytic model).
    pub peak_transient_bytes: u64,
    /// Final training loss at the end of the timed window.
    pub loss: f64,
    /// Median measured shard-imbalance ratio (max/mean per-shard wall
    /// time of the step's sharded host pass; 1.0 = balanced or serial).
    pub imbalance: f64,
    /// Shard-planner flavor the row ran under ("nominal" | "quantile" |
    /// "adaptive") — the imbalance column depends on it, so the schema
    /// records it (closing PR 4's "the CSV does not record --planner"
    /// gap).
    pub planner: String,
    /// Resolved native vector tier the row ran under ("on" | "off").
    /// Outputs are bitwise identical either way, but step_ms is not —
    /// a speedup computed across rows must not mix tiers, so the schema
    /// records it (same rationale as `planner`).
    pub simd: String,
    /// Hub-cache hit rate over the timed window: hits / (hits + misses)
    /// of leaf-hop cache lookups; 0.0 when `--hub-cache off` (no
    /// lookups happen at all).
    pub hub_hit_rate: f64,
    /// Total hub-cache entries (re)built over the timed window.
    pub hub_refreshes: u64,
}

pub const CSV_HEADER: &str = "dataset,variant,hops,fanout,batch,amp,repeat_seed,steps,step_ms,sample_ms,upload_ms,execute_ms,pairs_per_s,nodes_per_s,peak_transient_bytes,loss,imbalance,planner,simd,hub_hit_rate,hub_refreshes";

impl BenchRow {
    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{},{:.4},{:.4},{:.4},{:.4},{:.1},{:.1},{},{:.5},{:.4},{},{},{:.4},{}",
            self.dataset, self.variant, self.hops, self.fanout,
            self.batch, self.amp, self.repeat_seed, self.steps, self.step_ms,
            self.sample_ms, self.upload_ms, self.execute_ms, self.pairs_per_s,
            self.nodes_per_s, self.peak_transient_bytes, self.loss,
            self.imbalance, self.planner, self.simd, self.hub_hit_rate,
            self.hub_refreshes
        )
    }

    pub fn parse_csv(line: &str) -> Option<BenchRow> {
        let f: Vec<&str> = line.split(',').collect();
        if f.len() != 21 {
            return None;
        }
        // `hops` is derivable from the fanout label; derive it so the two
        // columns can never disagree (the written column stays validated
        // for schema sanity but is informational)
        let _written_hops: u32 = f[2].parse().ok()?;
        Some(BenchRow {
            dataset: f[0].to_string(),
            variant: f[1].to_string(),
            hops: f[3].split('x').count() as u32,
            fanout: f[3].to_string(),
            batch: f[4].parse().ok()?,
            amp: f[5] == "true",
            repeat_seed: f[6].parse().ok()?,
            steps: f[7].parse().ok()?,
            step_ms: f[8].parse().ok()?,
            sample_ms: f[9].parse().ok()?,
            upload_ms: f[10].parse().ok()?,
            execute_ms: f[11].parse().ok()?,
            pairs_per_s: f[12].parse().ok()?,
            nodes_per_s: f[13].parse().ok()?,
            peak_transient_bytes: f[14].parse().ok()?,
            loss: f[15].parse().ok()?,
            imbalance: f[16].parse().ok()?,
            planner: f[17].to_string(),
            simd: f[18].to_string(),
            hub_hit_rate: f[19].parse().ok()?,
            hub_refreshes: f[20].parse().ok()?,
        })
    }
}

/// One `throughput`-mode measurement of the host sampling/batch pipeline —
/// the schema of `results/throughput.csv`.
#[derive(Clone, Debug)]
pub struct ThroughputRow {
    pub dataset: String,
    /// Sampling depth (= `fanout` segment count).
    pub hops: u32,
    /// Canonical fanout label, e.g. "15x10".
    pub fanout: String,
    pub batch: u32,
    /// Sampler worker threads (resolved; 0=auto never appears here).
    pub threads: u32,
    pub prefetch: bool,
    pub steps: u32,
    /// Timed steps per second of wall clock — the headline pipeline metric.
    pub steps_per_s: f64,
    /// Median wall-clock per step (ms).
    pub step_ms: f64,
    /// Median critical-path sampling ms (block build, or prefetch wait).
    pub sample_ms: f64,
    /// Median sampling ms overlapped behind dispatch (prefetch on).
    pub overlap_ms: f64,
    /// Dispatch ms per step (emulated when no backend is available).
    pub dispatch_ms: f64,
    /// Fraction of host sampling work hidden behind dispatch, in [0, 1].
    pub utilization: f64,
    /// Median measured shard-imbalance ratio per step (max/mean per-shard
    /// wall time; 1.0 = balanced or serial) — makes planner regressions
    /// visible without a full bench run.
    pub imbalance: f64,
    /// Shard-planner flavor the run used (the imbalance column depends
    /// on it).
    pub planner: String,
    /// Hub-cache hit rate over the timed window (see
    /// [`BenchRow::hub_hit_rate`]); 0.0 when off.
    pub hub_hit_rate: f64,
    /// Total hub-cache entries (re)built over the timed window.
    pub hub_refreshes: u64,
}

pub const THROUGHPUT_CSV_HEADER: &str = "dataset,hops,fanout,batch,threads,prefetch,steps,steps_per_s,step_ms,sample_ms,overlap_ms,dispatch_ms,utilization,imbalance,planner,hub_hit_rate,hub_refreshes";

impl ThroughputRow {
    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{},{},{},{},{},{:.2},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4},{},{:.4},{}",
            self.dataset, self.hops, self.fanout, self.batch,
            self.threads, self.prefetch, self.steps, self.steps_per_s,
            self.step_ms, self.sample_ms, self.overlap_ms, self.dispatch_ms,
            self.utilization, self.imbalance, self.planner,
            self.hub_hit_rate, self.hub_refreshes
        )
    }

    pub fn parse_csv(line: &str) -> Option<ThroughputRow> {
        let f: Vec<&str> = line.split(',').collect();
        if f.len() != 17 {
            return None;
        }
        // derive hops from the fanout label (see BenchRow::parse_csv)
        let _written_hops: u32 = f[1].parse().ok()?;
        Some(ThroughputRow {
            dataset: f[0].to_string(),
            hops: f[2].split('x').count() as u32,
            fanout: f[2].to_string(),
            batch: f[3].parse().ok()?,
            threads: f[4].parse().ok()?,
            prefetch: f[5] == "true",
            steps: f[6].parse().ok()?,
            steps_per_s: f[7].parse().ok()?,
            step_ms: f[8].parse().ok()?,
            sample_ms: f[9].parse().ok()?,
            overlap_ms: f[10].parse().ok()?,
            dispatch_ms: f[11].parse().ok()?,
            utilization: f[12].parse().ok()?,
            imbalance: f[13].parse().ok()?,
            planner: f[14].to_string(),
            hub_hit_rate: f[15].parse().ok()?,
            hub_refreshes: f[16].parse().ok()?,
        })
    }
}

/// One `fsa serve --bench` grid cell — the schema of
/// `results/serving.csv`.
#[derive(Clone, Debug)]
pub struct ServingRow {
    pub dataset: String,
    /// Canonical *training* fanout label of the served model (the
    /// forward pass itself runs the depth-matched eval protocol).
    pub fanout: String,
    /// Execution backend the cell served on ("native" | "pjrt").
    pub backend: String,
    /// Shard-planner flavor (the imbalance column depends on it).
    pub planner: String,
    /// Micro-batch window the cell ran under, ms.
    pub batch_window_ms: f64,
    /// Micro-batch seed budget.
    pub max_batch: u32,
    /// Admission queue depth.
    pub queue_depth: u32,
    /// Offered arrival rate, requests/s (sum over clients).
    pub offered_rps: f64,
    /// Requests answered within the cell.
    pub completed: u64,
    /// Requests shed at admission (queue full).
    pub shed: u64,
    /// Completed requests per second of cell wall-clock.
    pub achieved_rps: f64,
    /// Enqueue→reply latency percentiles, ms.
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Median per-micro-batch shard imbalance (1.0 = balanced/serial).
    pub imbalance: f64,
    /// Requests that got an `Error` reply (micro-batch panic or engine
    /// failure, isolated to the batch).
    pub faults: u64,
    /// Bounded-backoff retries spent on transient persistence failures.
    pub retries: u64,
    /// Requests answered with a `Timeout` reply (deadline expired before
    /// dispatch).
    pub timeouts: u64,
    /// Hub-cache hit rate over the cell (see [`BenchRow::hub_hit_rate`]);
    /// 0.0 when off. Serve cells share one eval seed epoch, so warm
    /// cells approach the hub traffic share on skewed graphs.
    pub hub_hit_rate: f64,
    /// Total hub-cache entries (re)built over the cell.
    pub hub_refreshes: u64,
}

pub const SERVING_CSV_HEADER: &str = "dataset,fanout,backend,planner,batch_window_ms,max_batch,queue_depth,offered_rps,completed,shed,achieved_rps,p50_ms,p95_ms,p99_ms,imbalance,faults,retries,timeouts,hub_hit_rate,hub_refreshes";

impl ServingRow {
    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{},{},{:.3},{},{},{:.1},{},{},{:.2},{:.4},{:.4},{:.4},{:.4},{},{},{},{:.4},{}",
            self.dataset, self.fanout, self.backend, self.planner,
            self.batch_window_ms, self.max_batch, self.queue_depth,
            self.offered_rps, self.completed, self.shed, self.achieved_rps,
            self.p50_ms, self.p95_ms, self.p99_ms, self.imbalance,
            self.faults, self.retries, self.timeouts, self.hub_hit_rate,
            self.hub_refreshes
        )
    }

    pub fn parse_csv(line: &str) -> Option<ServingRow> {
        let f: Vec<&str> = line.split(',').collect();
        if f.len() != 20 {
            return None;
        }
        Some(ServingRow {
            dataset: f[0].to_string(),
            fanout: f[1].to_string(),
            backend: f[2].to_string(),
            planner: f[3].to_string(),
            batch_window_ms: f[4].parse().ok()?,
            max_batch: f[5].parse().ok()?,
            queue_depth: f[6].parse().ok()?,
            offered_rps: f[7].parse().ok()?,
            completed: f[8].parse().ok()?,
            shed: f[9].parse().ok()?,
            achieved_rps: f[10].parse().ok()?,
            p50_ms: f[11].parse().ok()?,
            p95_ms: f[12].parse().ok()?,
            p99_ms: f[13].parse().ok()?,
            imbalance: f[14].parse().ok()?,
            faults: f[15].parse().ok()?,
            retries: f[16].parse().ok()?,
            timeouts: f[17].parse().ok()?,
            hub_hit_rate: f[18].parse().ok()?,
            hub_refreshes: f[19].parse().ok()?,
        })
    }
}

/// Write serving rows (with header) to a CSV file.
pub fn write_serving_csv(path: &Path,
                         rows: &[ServingRow]) -> std::io::Result<()> {
    let mut out = String::with_capacity(rows.len() * 96 + 128);
    out.push_str(SERVING_CSV_HEADER);
    out.push('\n');
    for r in rows {
        let _ = writeln!(out, "{}", r.to_csv());
    }
    crate::util::atomic_write(path, out.as_bytes())
}

/// Read serving rows back (skipping header and malformed lines).
pub fn read_serving_csv(path: &Path) -> std::io::Result<Vec<ServingRow>> {
    let text = std::fs::read_to_string(path)?;
    Ok(text.lines().skip(1).filter_map(ServingRow::parse_csv).collect())
}

/// One worker's ledger from a distributed training session
/// (`fsa train --workers N` → `dist.csv`, one row per rank).
#[derive(Clone, Debug, PartialEq)]
pub struct DistRow {
    /// Session worker count.
    pub workers: u32,
    pub rank: u32,
    /// Optimizer steps this rank contributed at least one micro to.
    pub steps: u32,
    /// Micro-batches whose gradients the coordinator accepted from
    /// this rank (first-frame-wins under re-dispatch).
    pub micros: u64,
    /// Seeds across those accepted micros.
    pub seeds: u64,
    /// Fraction of those seeds inside the rank's original node shard.
    pub local_frac: f64,
    /// Worker-side compute time across accepted micros, ms.
    pub step_ms: f64,
    /// Dispatch-to-acceptance time minus compute, ms (protocol +
    /// queueing overhead; coarse, clamped at zero).
    pub comm_ms: f64,
    /// Edge share of the shard(s) this rank ended the session owning.
    pub edge_share: f64,
    /// Worst relative deviation of any initial shard's edge share from
    /// the ideal `1/workers` (global, repeated on every row).
    pub edge_load_dev: f64,
    /// Dead peers' shards this rank absorbed.
    pub reassigned: u32,
    /// Whether the rank was still alive at session end.
    pub completed: bool,
}

pub const DIST_CSV_HEADER: &str = "workers,rank,steps,micros,seeds,local_frac,step_ms,comm_ms,edge_share,edge_load_dev,reassigned,completed";

impl DistRow {
    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{},{},{},{:.4},{:.4},{:.4},{:.4},{:.4},{},{}",
            self.workers, self.rank, self.steps, self.micros, self.seeds,
            self.local_frac, self.step_ms, self.comm_ms, self.edge_share,
            self.edge_load_dev, self.reassigned, self.completed
        )
    }

    pub fn parse_csv(line: &str) -> Option<DistRow> {
        let f: Vec<&str> = line.split(',').collect();
        if f.len() != 12 {
            return None;
        }
        Some(DistRow {
            workers: f[0].parse().ok()?,
            rank: f[1].parse().ok()?,
            steps: f[2].parse().ok()?,
            micros: f[3].parse().ok()?,
            seeds: f[4].parse().ok()?,
            local_frac: f[5].parse().ok()?,
            step_ms: f[6].parse().ok()?,
            comm_ms: f[7].parse().ok()?,
            edge_share: f[8].parse().ok()?,
            edge_load_dev: f[9].parse().ok()?,
            reassigned: f[10].parse().ok()?,
            completed: f[11].parse().ok()?,
        })
    }
}

/// Write per-worker dist rows (with header) to a CSV file.
pub fn write_dist_csv(path: &Path, rows: &[DistRow]) -> std::io::Result<()> {
    let mut out = String::with_capacity(rows.len() * 96 + 128);
    out.push_str(DIST_CSV_HEADER);
    out.push('\n');
    for r in rows {
        let _ = writeln!(out, "{}", r.to_csv());
    }
    crate::util::atomic_write(path, out.as_bytes())
}

/// Read dist rows back (skipping header and malformed lines).
pub fn read_dist_csv(path: &Path) -> std::io::Result<Vec<DistRow>> {
    let text = std::fs::read_to_string(path)?;
    Ok(text.lines().skip(1).filter_map(DistRow::parse_csv).collect())
}

/// Write throughput rows (with header) to a CSV file.
pub fn write_throughput_csv(path: &Path,
                            rows: &[ThroughputRow]) -> std::io::Result<()> {
    let mut out = String::with_capacity(rows.len() * 96 + 128);
    out.push_str(THROUGHPUT_CSV_HEADER);
    out.push('\n');
    for r in rows {
        let _ = writeln!(out, "{}", r.to_csv());
    }
    crate::util::atomic_write(path, out.as_bytes())
}

/// Write rows (with header) to a CSV file.
pub fn write_csv(path: &Path, rows: &[BenchRow]) -> std::io::Result<()> {
    let mut out = String::with_capacity(rows.len() * 96 + 128);
    out.push_str(CSV_HEADER);
    out.push('\n');
    for r in rows {
        let _ = writeln!(out, "{}", r.to_csv());
    }
    crate::util::atomic_write(path, out.as_bytes())
}

/// Read rows back (skipping the header and malformed lines).
pub fn read_csv(path: &Path) -> std::io::Result<Vec<BenchRow>> {
    let text = std::fs::read_to_string(path)?;
    Ok(text.lines().skip(1).filter_map(BenchRow::parse_csv).collect())
}

/// Median row over repeats: groups rows by configuration key and reduces
/// every numeric field to its median (the paper reports medians of 3).
pub fn median_over_repeats(rows: &[BenchRow]) -> Vec<BenchRow> {
    use std::collections::BTreeMap;
    let mut groups: BTreeMap<String, Vec<&BenchRow>> = BTreeMap::new();
    for r in rows {
        // planner and simd are part of the key: imbalance medians across
        // planner flavors — or step-time medians across vector tiers —
        // would mix apples and oranges
        let key = format!("{}|{}|{}|{}|{}|{}|{}|{}", r.dataset, r.variant,
                          r.hops, r.fanout, r.batch, r.amp, r.planner,
                          r.simd);
        groups.entry(key).or_default().push(r);
    }
    groups
        .into_values()
        .map(|g| {
            let med = |f: fn(&BenchRow) -> f64| {
                median(&g.iter().map(|r| f(r)).collect::<Vec<_>>())
            };
            let first = g[0];
            BenchRow {
                dataset: first.dataset.clone(),
                variant: first.variant.clone(),
                hops: first.hops,
                fanout: first.fanout.clone(),
                batch: first.batch,
                amp: first.amp,
                repeat_seed: 0,
                steps: first.steps,
                step_ms: med(|r| r.step_ms),
                sample_ms: med(|r| r.sample_ms),
                upload_ms: med(|r| r.upload_ms),
                execute_ms: med(|r| r.execute_ms),
                pairs_per_s: med(|r| r.pairs_per_s),
                nodes_per_s: med(|r| r.nodes_per_s),
                peak_transient_bytes: med(|r| r.peak_transient_bytes as f64)
                    as u64,
                loss: med(|r| r.loss),
                imbalance: med(|r| r.imbalance),
                planner: first.planner.clone(),
                simd: first.simd.clone(),
                hub_hit_rate: med(|r| r.hub_hit_rate),
                hub_refreshes: med(|r| r.hub_refreshes as f64) as u64,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = summarize(&[4.0, 1.0, 3.0, 2.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&s, 0.0), 1.0);
        assert_eq!(percentile_sorted(&s, 100.0), 4.0);
        assert_eq!(percentile_sorted(&s, 50.0), 2.5);
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(summarize(&[]).median, 0.0);
        assert_eq!(summarize(&[7.0]).median, 7.0);
    }

    fn sample_row(seed: u64, step_ms: f64) -> BenchRow {
        BenchRow {
            dataset: "tiny".into(),
            variant: "fsa".into(),
            hops: 2,
            fanout: "5x3".into(),
            batch: 64,
            amp: true,
            repeat_seed: seed,
            steps: 30,
            step_ms,
            sample_ms: 0.0,
            upload_ms: 0.1,
            execute_ms: step_ms - 0.1,
            pairs_per_s: 1e6,
            nodes_per_s: 1e4,
            peak_transient_bytes: 123456,
            loss: 2.0,
            imbalance: 1.25,
            planner: "quantile".into(),
            simd: "on".into(),
            hub_hit_rate: 0.75,
            hub_refreshes: 12,
        }
    }

    #[test]
    fn csv_round_trip() {
        let row = sample_row(42, 1.25);
        let parsed = BenchRow::parse_csv(&row.to_csv()).unwrap();
        assert_eq!(parsed.dataset, "tiny");
        assert_eq!(parsed.fanout, "5x3");
        assert_eq!(parsed.repeat_seed, 42);
        assert!((parsed.step_ms - 1.25).abs() < 1e-9);
        assert_eq!(parsed.peak_transient_bytes, 123456);
        assert!((parsed.imbalance - 1.25).abs() < 1e-9);
        assert_eq!(parsed.planner, "quantile");
        assert_eq!(parsed.simd, "on");
        assert!((parsed.hub_hit_rate - 0.75).abs() < 1e-9);
        assert_eq!(parsed.hub_refreshes, 12);
        assert_eq!(CSV_HEADER.split(',').count(),
                   row.to_csv().split(',').count());
    }

    /// Pin both schemas exactly: 21 bench columns / 17 throughput
    /// columns, with the hub-cache pair (`hub_hit_rate,hub_refreshes`)
    /// appended last. A drive-by column reorder or rename must fail
    /// here, not in a downstream reader.
    #[test]
    fn csv_schemas_are_pinned() {
        assert_eq!(
            CSV_HEADER,
            "dataset,variant,hops,fanout,batch,amp,repeat_seed,steps,\
             step_ms,sample_ms,upload_ms,execute_ms,pairs_per_s,\
             nodes_per_s,peak_transient_bytes,loss,imbalance,planner,\
             simd,hub_hit_rate,hub_refreshes");
        assert_eq!(CSV_HEADER.split(',').count(), 21);
        assert_eq!(
            THROUGHPUT_CSV_HEADER,
            "dataset,hops,fanout,batch,threads,prefetch,steps,\
             steps_per_s,step_ms,sample_ms,overlap_ms,dispatch_ms,\
             utilization,imbalance,planner,hub_hit_rate,hub_refreshes");
        assert_eq!(THROUGHPUT_CSV_HEADER.split(',').count(), 17);
        // rows with the previous (20-/16-column) schema no longer parse:
        // the reader rejects rather than misassigns
        let new = sample_row(42, 1.0).to_csv();
        let old_20_cols = new.rsplit_once(',').unwrap().0;
        assert!(BenchRow::parse_csv(old_20_cols).is_none());
    }

    fn sample_dist_row(rank: u32) -> DistRow {
        DistRow {
            workers: 4,
            rank,
            steps: 30,
            micros: 120,
            seeds: 7_680,
            local_frac: 0.2531,
            step_ms: 812.5,
            comm_ms: 90.25,
            edge_share: 0.2498,
            edge_load_dev: 0.0125,
            reassigned: 1,
            completed: true,
        }
    }

    /// Pin the dist schema exactly (12 columns) and reject truncated
    /// rows, mirroring the bench/throughput/serving guarantees.
    #[test]
    fn dist_csv_schema_is_pinned() {
        assert_eq!(
            DIST_CSV_HEADER,
            "workers,rank,steps,micros,seeds,local_frac,step_ms,comm_ms,\
             edge_share,edge_load_dev,reassigned,completed");
        assert_eq!(DIST_CSV_HEADER.split(',').count(), 12);
        let row = sample_dist_row(2);
        assert_eq!(row.to_csv().split(',').count(), 12);
        let parsed = DistRow::parse_csv(&row.to_csv()).unwrap();
        assert_eq!(parsed, row);
        let truncated = row.to_csv();
        let truncated = truncated.rsplit_once(',').unwrap().0;
        assert!(DistRow::parse_csv(truncated).is_none());
        assert!(DistRow::parse_csv("not,a,row").is_none());
    }

    #[test]
    fn dist_csv_file_round_trip() {
        let dir = std::env::temp_dir().join("fsa_metrics_dist_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("dist.csv");
        let rows: Vec<DistRow> = (0..3).map(sample_dist_row).collect();
        write_dist_csv(&p, &rows).unwrap();
        let back = read_dist_csv(&p).unwrap();
        assert_eq!(back, rows);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn csv_file_round_trip() {
        let dir = std::env::temp_dir().join("fsa_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bench.csv");
        let rows = vec![sample_row(42, 1.0), sample_row(43, 2.0)];
        write_csv(&p, &rows).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[1].repeat_seed, 43);
    }

    #[test]
    fn median_over_repeats_reduces() {
        let rows = vec![sample_row(42, 1.0), sample_row(43, 5.0),
                        sample_row(44, 2.0)];
        let med = median_over_repeats(&rows);
        assert_eq!(med.len(), 1);
        assert_eq!(med[0].step_ms, 2.0);
    }

    #[test]
    fn throughput_csv_round_trip() {
        let row = ThroughputRow {
            dataset: "arxiv_sim".into(),
            hops: 2,
            fanout: "15x10".into(),
            batch: 1024,
            threads: 4,
            prefetch: true,
            steps: 30,
            steps_per_s: 123.45,
            step_ms: 8.1,
            sample_ms: 0.2,
            overlap_ms: 5.5,
            dispatch_ms: 2.0,
            utilization: 0.96,
            imbalance: 1.08,
            planner: "adaptive".into(),
            hub_hit_rate: 0.5,
            hub_refreshes: 7,
        };
        let parsed = ThroughputRow::parse_csv(&row.to_csv()).unwrap();
        assert_eq!(parsed.dataset, "arxiv_sim");
        assert_eq!(parsed.threads, 4);
        assert!(parsed.prefetch);
        assert!((parsed.steps_per_s - 123.45).abs() < 1e-6);
        assert!((parsed.utilization - 0.96).abs() < 1e-9);
        assert!((parsed.imbalance - 1.08).abs() < 1e-9);
        assert_eq!(parsed.planner, "adaptive");
        assert!((parsed.hub_hit_rate - 0.5).abs() < 1e-9);
        assert_eq!(parsed.hub_refreshes, 7);
        assert_eq!(THROUGHPUT_CSV_HEADER.split(',').count(),
                   row.to_csv().split(',').count());
    }

    fn sample_serving_row() -> ServingRow {
        ServingRow {
            dataset: "tiny".into(),
            fanout: "5x3".into(),
            backend: "native".into(),
            planner: "adaptive".into(),
            batch_window_ms: 2.0,
            max_batch: 512,
            queue_depth: 64,
            offered_rps: 800.0,
            completed: 731,
            shed: 12,
            achieved_rps: 726.3,
            p50_ms: 1.2,
            p95_ms: 3.4,
            p99_ms: 5.6,
            imbalance: 1.07,
            faults: 3,
            retries: 1,
            timeouts: 2,
            hub_hit_rate: 0.9,
            hub_refreshes: 4,
        }
    }

    #[test]
    fn serving_csv_round_trip() {
        let row = sample_serving_row();
        let parsed = ServingRow::parse_csv(&row.to_csv()).unwrap();
        assert_eq!(parsed.dataset, "tiny");
        assert_eq!(parsed.backend, "native");
        assert_eq!(parsed.planner, "adaptive");
        assert_eq!(parsed.max_batch, 512);
        assert_eq!(parsed.queue_depth, 64);
        assert_eq!(parsed.completed, 731);
        assert_eq!(parsed.shed, 12);
        assert!((parsed.offered_rps - 800.0).abs() < 1e-9);
        assert!((parsed.achieved_rps - 726.3).abs() < 1e-6);
        assert!((parsed.p99_ms - 5.6).abs() < 1e-6);
        assert!((parsed.imbalance - 1.07).abs() < 1e-6);
        assert_eq!(parsed.faults, 3);
        assert_eq!(parsed.retries, 1);
        assert_eq!(parsed.timeouts, 2);
        assert!((parsed.hub_hit_rate - 0.9).abs() < 1e-9);
        assert_eq!(parsed.hub_refreshes, 4);
        assert_eq!(SERVING_CSV_HEADER.split(',').count(),
                   row.to_csv().split(',').count());
    }

    /// Pin the serving schema exactly, same contract as
    /// `csv_schemas_are_pinned`: 20 columns, this order, and rows from
    /// an older (shorter) schema are rejected rather than misassigned.
    #[test]
    fn serving_csv_schema_is_pinned() {
        assert_eq!(
            SERVING_CSV_HEADER,
            "dataset,fanout,backend,planner,batch_window_ms,max_batch,\
             queue_depth,offered_rps,completed,shed,achieved_rps,\
             p50_ms,p95_ms,p99_ms,imbalance,faults,retries,timeouts,\
             hub_hit_rate,hub_refreshes");
        assert_eq!(SERVING_CSV_HEADER.split(',').count(), 20);
        let new = sample_serving_row().to_csv();
        let old_19_cols = new.rsplit_once(',').unwrap().0;
        assert!(ServingRow::parse_csv(old_19_cols).is_none());
    }

    #[test]
    fn serving_csv_file_round_trip() {
        let dir = std::env::temp_dir().join("fsa_metrics_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("serving.csv");
        let rows = vec![sample_serving_row(), sample_serving_row()];
        write_serving_csv(&p, &rows).unwrap();
        let back = read_serving_csv(&p).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].completed, 731);
    }

    #[test]
    fn timer_runs_forward() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.ms() >= 1.0);
    }
}
