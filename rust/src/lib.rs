//! # FuseSampleAgg — fused neighbor sampling + aggregation for mini-batch GNNs
//!
//! Rust + JAX + Pallas reproduction of *"FuseSampleAgg: Fused Neighbor
//! Sampling and Aggregation for Mini-batch GNNs"* (Stanković, 2025).
//!
//! This crate is **Layer 3** of the three-layer architecture (see DESIGN.md):
//! it owns the entire training request path — synthetic dataset generation,
//! CSR graph storage, the DGL-like host-side neighbor sampler used by the
//! baseline, mini-batch scheduling, the PJRT runtime that executes the
//! AOT-compiled artifacts (Layer 2 JAX models calling the Layer 1 Pallas
//! fused kernels), step timing, transient-memory accounting, and the
//! benchmark harness that regenerates every table and figure of the paper.
//!
//! Python never runs on the request path: `make artifacts` lowers the models
//! to HLO text once; the `fsa` binary is self-contained afterwards.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`rng`] | deterministic counter RNG (bitwise-identical to the kernel) |
//! | [`fanout`] | the ordered per-hop [`fanout::Fanouts`] list (depth = L) |
//! | [`json`] | minimal JSON parser/writer (manifest, configs) |
//! | [`graph`] | CSR storage, degree stats, expected-subtree shard planner |
//! | [`gen`] | synthetic dataset registry (`arxiv_sim`, `reddit_sim`, …) |
//! | [`sampler`] | host neighbor sampler + baseline block builder |
//! | [`kernel`] | native CPU engine: fused + baseline step variants |
//! | [`runtime`] | PJRT client, artifact manifest, backend seam |
//! | [`memory`] | transient-memory meter + analytic block model |
//! | [`metrics`] | timers, robust stats, CSV logging |
//! | [`engine`] | session facade: params, optimizer, planner, infer/step |
//! | [`coordinator`] | training loop driver, batch pipeline, profiling |
//! | [`dist`] | localhost multi-process data-parallel training |
//! | [`serve`] | micro-batched online inference queue + load generator |
//! | [`bench`] | grid runner + renderers + host-pipeline throughput mode |
//! | [`cli`] | hand-rolled argument parser and subcommands |
//! | [`xla`] | stand-in for the PJRT bindings (see its module docs) |

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod dist;
pub mod engine;
pub mod fanout;
pub mod gen;
pub mod graph;
pub mod json;
pub mod kernel;
pub mod memory;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod sampler;
pub mod serve;
pub mod util;
pub mod xla;
