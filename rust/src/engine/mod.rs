//! Session-owning `Engine` facade — the one object that holds a live
//! model.
//!
//! Everything stateful about a session lives here: the dataset handle,
//! the parameter/optimizer tensors (inside the backend), the shared
//! planner cost model and its persistence, the host batch pipeline, and
//! the deterministic RNG schedule. The two entry points that drive a
//! session are thin layers on top:
//!
//! - [`crate::coordinator::Trainer`] is a training loop calling
//!   [`Engine::step`];
//! - [`crate::serve`] is a micro-batching request loop calling
//!   [`Engine::infer`].
//!
//! [`Engine::infer`] is the *single* forward-only inference path:
//! `evaluate` computes accuracy over it, and the serve path returns its
//! logits per request. It allocates no gradient or optimizer buffers and
//! draws from a fixed base seed (`mix(seed ^ 0xEAE1)`, the eval
//! schedule), keyed per *node* rather than per batch position — which is
//! why per-seed logits are bitwise invariant to how requests are grouped
//! into micro-batches (pinned in `rust/tests/serve.rs`).

pub mod checkpoint;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::pipeline::{self, BatchPrefetcher, BatchScheduler,
                                   PreparedBatch};
use crate::coordinator::{StepTiming, TrainConfig, Variant};
use crate::fanout::Fanouts;
use crate::gen::{Dataset, Split};
use crate::graph::cost::shared_session_model;
use crate::graph::state::{unix_now, PlannerState, StateEntry, StateKey};
use crate::graph::{lock_model, SharedCostModel};
use crate::kernel::NativeBackend;
use crate::memory::MemoryMeter;
use crate::rng::mix;
use crate::runtime::backend::{ensure_pjrt_depth, Backend, BackendChoice,
                              PjrtBackend, StepInputs};
use crate::runtime::faults::{self, FaultSite};
use crate::runtime::Runtime;
use crate::sampler::{self, ParallelSampler};
use crate::xla;

pub use checkpoint::{ParamsCheckpoint, TrainState};

/// Inference chunk size: forward passes are dispatched at most this many
/// seeds at a time (matches the eval artifact batch, and bounds the
/// transient working set of one serve micro-batch).
pub const INFER_CHUNK: usize = 512;

/// Re-exported so engine users need no `coordinator` import to build
/// a session.
pub use crate::coordinator::DatasetCache;

/// A live session for one configuration: owns the model, drives the
/// backend. See the module docs for the facade boundary.
pub struct Engine<'rt> {
    rt: &'rt Runtime,
    pub cfg: TrainConfig,
    backend: Box<dyn Backend + 'rt>,
    pub ds: Arc<Dataset>,
    pub step_count: usize,
    // host batch pipeline
    sched: BatchScheduler,
    sampler: ParallelSampler,
    prefetcher: Option<BatchPrefetcher>,
    pub meter: MemoryMeter,
    /// The session-shared planner model (adaptive flavor only): the
    /// fused kernel, the host sampler, and the prefetch thread all plan
    /// and observe through it.
    planner_model: Option<SharedCostModel>,
    /// Where (and under which key) to persist the adaptive weights at
    /// shutdown (`cfg.planner_state`, resolved), plus the
    /// `steps_observed` baseline inherited from the warm start — only
    /// sessions that observed *past* that baseline save, so re-running
    /// without new measurements never refreshes the staleness stamp.
    planner_persist: Option<(PathBuf, StateKey, u64)>,
    /// Bounded-backoff retries consumed by persistence (checkpoint
    /// writes) so far this session; surfaces as serving.csv's `retries`
    /// column. A `Cell` because saving takes `&self`.
    retries: std::cell::Cell<u64>,
}

/// One-time note when `Auto` falls back from PJRT to the native engine.
pub(crate) fn note_native_fallback(err: &anyhow::Error) {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        eprintln!("note: PJRT backend unavailable ({err:#}); \
                   using the native CPU engine");
    });
}

impl<'rt> Engine<'rt> {
    pub fn new(rt: &'rt Runtime, cache: &mut DatasetCache,
               cfg: TrainConfig) -> Result<Engine<'rt>> {
        let ds = cache.get(rt, &cfg.dataset)?;
        let shared = Self::session_model(&ds, &cfg);
        let backend: Box<dyn Backend + 'rt> = match cfg.backend {
            BackendChoice::Native => Box::new(
                Self::native_backend(rt, &ds, &cfg, shared.clone())?),
            BackendChoice::Pjrt => Box::new(Self::pjrt_backend(rt, &ds,
                                                               &cfg)?),
            BackendChoice::Auto => match Self::pjrt_backend(rt, &ds, &cfg) {
                Ok(b) => Box::new(b),
                Err(e) => {
                    note_native_fallback(&e);
                    Box::new(Self::native_backend(rt, &ds, &cfg,
                                                  shared.clone())?)
                }
            },
        };
        Self::with_backend(rt, cfg, ds, backend, shared)
    }

    /// Build an engine on an explicit PJRT artifact (e.g. a §Perf tile
    /// variant) whose dims must match `cfg`.
    pub fn new_named(rt: &'rt Runtime, cache: &mut DatasetCache,
                     cfg: TrainConfig, artifact: &str) -> Result<Engine<'rt>> {
        let ds = cache.get(rt, &cfg.dataset)?;
        let shared = Self::session_model(&ds, &cfg);
        let backend = PjrtBackend::new(
            rt, &ds, artifact, cfg.variant == Variant::Fsa, &cfg.fanouts,
            cfg.batch, cfg.save_indices, cfg.seed)?;
        Self::with_backend(rt, cfg, ds, Box::new(backend), shared)
    }

    /// The session's shared planner model (`Some` for adaptive only —
    /// see [`crate::graph::cost::shared_session_model`]).
    fn session_model(ds: &Arc<Dataset>,
                     cfg: &TrainConfig) -> Option<SharedCostModel> {
        shared_session_model(&ds.graph, &cfg.fanouts, cfg.planner)
    }

    fn pjrt_backend(rt: &'rt Runtime, ds: &Arc<Dataset>,
                    cfg: &TrainConfig) -> Result<PjrtBackend<'rt>> {
        ensure_pjrt_depth(&cfg.fanouts)?;
        let k1 = cfg.fanouts.k(0);
        let k2 = if cfg.fanouts.depth() == 2 { cfg.fanouts.k(1) } else { 0 };
        let name = rt.manifest.find_train(
            &cfg.artifact_variant(), &cfg.dataset, k1, k2,
            cfg.batch, cfg.amp, cfg.save_indices)?.name.clone();
        PjrtBackend::new(rt, ds, &name, cfg.variant == Variant::Fsa,
                         &cfg.fanouts, cfg.batch, cfg.save_indices, cfg.seed)
    }

    fn native_backend(rt: &Runtime, ds: &Arc<Dataset>, cfg: &TrainConfig,
                      shared: Option<SharedCostModel>)
                      -> Result<NativeBackend> {
        let native_cfg = cfg.native_config(rt.manifest.hidden);
        match shared {
            Some(model) => NativeBackend::with_shared_model(
                ds.clone(), native_cfg, rt.manifest.adamw, model),
            None => NativeBackend::new(ds.clone(), native_cfg,
                                       rt.manifest.adamw),
        }
    }

    fn with_backend(rt: &'rt Runtime, cfg: TrainConfig, ds: Arc<Dataset>,
                    backend: Box<dyn Backend + 'rt>,
                    planner_model: Option<SharedCostModel>)
                    -> Result<Engine<'rt>> {
        let sched = BatchScheduler::new(&ds, cfg.batch, cfg.seed)?;
        let mut sampler =
            ParallelSampler::with_planner(cfg.threads, cfg.planner);
        if let Some(m) = &planner_model {
            sampler = sampler.with_model(m.clone());
        }
        // warm-start before any planning happens, so the very first
        // batch already cuts with the persisted weights
        let planner_persist = Self::load_planner_state(
            &cfg, &sampler, planner_model.as_ref());
        let prefetcher = cfg.prefetch.then(|| {
            // a dedicated sampler for the prefetch thread: same shared
            // model and clock, private imbalance accumulator
            BatchPrefetcher::spawn(ds.clone(), cfg.host_work(),
                                   cfg.fanouts.clone(),
                                   sampler.fresh_stats())
        });
        Ok(Engine {
            rt,
            cfg,
            backend,
            ds,
            step_count: 0,
            sched,
            sampler,
            prefetcher,
            meter: MemoryMeter::new(),
            planner_model,
            planner_persist,
            retries: std::cell::Cell::new(0),
        })
    }

    /// Warm-start the shared model from `cfg.planner_state` (adaptive
    /// flavor only). Corrupt or mismatched files degrade to uniform
    /// weights with a warning; a found entry is logged so a second run
    /// can be seen to warm-start (the CI smoke greps for it). Returns
    /// the resolved (path, key) to save back to at shutdown.
    fn load_planner_state(cfg: &TrainConfig, sampler: &ParallelSampler,
                          model: Option<&SharedCostModel>)
                          -> Option<(PathBuf, StateKey, u64)> {
        let (path, model) = match (&cfg.planner_state, model) {
            (Some(p), Some(m)) => (p.clone(), m),
            _ => return None,
        };
        // key on the *resolved* worker count (0 = auto is a CLI detail)
        let key = StateKey::for_session(sampler.threads(), cfg.planner);
        let state = PlannerState::load(&path);
        let mut baseline = 0u64;
        if let Some(entry) = state.get(&key) {
            let mut m = lock_model(model);
            if m.warm_start(&entry.weights, entry.steps_observed) {
                baseline = entry.steps_observed;
                eprintln!("planner-state: warm-start from {} \
                           ({} steps observed, weights {:?})",
                          path.display(), entry.steps_observed,
                          entry.weights);
            } else {
                eprintln!("warning: planner-state entry for {} is \
                           unusable; starting from uniform weights",
                          key.as_string());
            }
        }
        Some((path, key, baseline))
    }

    /// Persist the adaptive weights through the lock-guarded
    /// freshness-merging save ([`PlannerState::merge_save`]): the file
    /// is re-read inside the lock and the entry only lands if it
    /// carries more evidence than the incumbent, so concurrent sessions
    /// sharing the file (serve shutting down while train exits) cannot
    /// clobber each other's same-key weights. Called at drop; callable
    /// explicitly by tests. Sessions that observed nothing beyond their
    /// warm-start baseline save nothing — a serial (or
    /// measurement-free) run must neither clobber measured state with
    /// uniform weights nor refresh the `saved_unix` staleness stamp
    /// without new evidence.
    pub fn save_planner_state(&self) {
        let (Some((path, key, baseline)), Some(model)) =
            (&self.planner_persist, &self.planner_model)
        else {
            return;
        };
        let (weights, steps) = {
            let m = lock_model(model);
            (m.worker_weights().to_vec(), m.steps_observed())
        };
        if weights.is_empty() || steps <= *baseline {
            return;
        }
        let entry = StateEntry {
            weights,
            steps_observed: steps,
            saved_unix: unix_now(),
        };
        // warn-only: planner state is a warm-start optimization, never
        // worth failing a session over (the chaos `state-write` site
        // exercises exactly this degradation)
        let res: Result<bool> = {
            let op = self.cfg.faults.begin(FaultSite::StateWrite);
            faults::inject(self.cfg.faults.as_ref(), FaultSite::StateWrite,
                           op)
                .and_then(|()| Ok(PlannerState::merge_save(path, key,
                                                           entry)?))
        };
        match res {
            Ok(true) => eprintln!("planner-state: saved {} ({} steps \
                                   observed) to {}",
                                  key.as_string(), steps, path.display()),
            Ok(false) => eprintln!("planner-state: kept fresher on-disk \
                                    entry for {} (ours: {} steps observed)",
                                   key.as_string(), steps),
            Err(e) => eprintln!("warning: could not save planner-state \
                                 {}: {e}", path.display()),
        }
    }

    /// Current adaptive per-worker weights (None for other flavors or
    /// before any feedback/warm-start).
    pub fn planner_weights(&self) -> Option<Vec<f64>> {
        let m = self.planner_model.as_ref()?;
        let w = lock_model(m).worker_weights().to_vec();
        (!w.is_empty()).then_some(w)
    }

    /// The execution backend actually in use ("native" | "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Next batch of seed nodes (reshuffles at epoch boundaries; identical
    /// order across variants for the same seed). Draws from the shared
    /// scheduler — mixing manual draws with prefetching degrades the
    /// prefetcher to the synchronous path (see [`Engine::acquire_batch`]).
    pub fn next_batch(&mut self) -> Vec<i32> {
        self.sched.next_seeds()
    }

    /// Per-step base seed: shared schedule across variants so both sample
    /// the same neighborhoods at the same step (paired comparisons).
    pub fn step_base_seed(&self) -> u64 {
        mix(self.cfg.seed.wrapping_add(self.step_count as u64))
    }

    /// Run one training step; returns the timing breakdown.
    pub fn step(&mut self) -> Result<StepTiming> {
        let prepared = self.acquire_batch()?;
        self.step_prepared(prepared)
    }

    /// Run one step on explicit seeds (used by tests and the e2e example).
    /// Always samples synchronously; does not consume the scheduler.
    pub fn step_with_seeds(&mut self, seeds: &[i32]) -> Result<StepTiming> {
        let prepared = pipeline::prepare_batch(
            &self.ds, self.cfg.host_work(), &self.cfg.fanouts,
            &self.sampler, self.step_count, seeds.to_vec(),
            self.step_base_seed());
        self.step_prepared(prepared)
    }

    /// Obtain the batch for the current step — synchronously, or from the
    /// double-buffered prefetch worker (keeping one batch in flight behind
    /// the one being consumed so sampling overlaps dispatch).
    fn acquire_batch(&mut self) -> Result<PreparedBatch> {
        if let Some(p) = &mut self.prefetcher {
            let prepared = p.next_batch(&mut self.sched)?;
            if prepared.step == self.step_count {
                return Ok(prepared);
            }
            // Schedule desync: explicit-seed steps advanced `step_count`
            // past the prefetched stream. Keep the seed order (the drawn
            // batch is still next) but resample synchronously with the
            // base seed the legacy schedule mandates for this step.
            return Ok(pipeline::prepare_batch(
                &self.ds, self.cfg.host_work(), &self.cfg.fanouts,
                &self.sampler, self.step_count, prepared.seeds,
                self.step_base_seed()));
        }
        let seeds = self.sched.next_seeds();
        Ok(pipeline::prepare_batch(
            &self.ds, self.cfg.host_work(), &self.cfg.fanouts, &self.sampler,
            self.step_count, seeds, self.step_base_seed()))
    }

    /// Dispatch one prepared batch through the backend and account it.
    fn step_prepared(&mut self, prepared: PreparedBatch) -> Result<StepTiming> {
        let mut t = StepTiming::default();
        let b = self.cfg.batch;
        if prepared.seeds.len() != b {
            bail!("expected {b} seeds, got {}", prepared.seeds.len());
        }
        match prepared.wait_ms {
            // synchronous build: sampling is the critical path
            None => t.sample_ms = prepared.sample_ms,
            // prefetched: only the wait is critical; the build overlapped
            Some(wait) => {
                t.sample_ms = wait;
                t.sample_overlap_ms = prepared.sample_ms;
            }
        }

        // ---- synchronized dispatch through the backend seam
        self.meter.reset_step();
        let inp = StepInputs {
            seeds: &prepared.seeds,
            labels: &prepared.labels,
            base: prepared.base,
            block: prepared.block.as_ref(),
        };
        let out = self.backend.train_step(self.step_count, &inp,
                                          &mut self.meter)?;
        t.upload_ms = out.upload_ms;
        t.execute_ms = out.execute_ms;
        t.post_ms = out.post_ms;
        t.loss = out.loss;
        // shard balance: the engine's batch shards when it sharded, else
        // the host sampler's block shards, else serial (1.0)
        t.imbalance = out
            .shard_stats
            .as_ref()
            .map(|s| s.imbalance())
            .or(prepared.sample_imbalance)
            .unwrap_or(1.0);
        t.hub_hits = out.hub_hits;
        t.hub_misses = out.hub_misses;
        t.hub_refreshes = out.hub_refreshes;
        t.transient_bytes = self.meter.peak();
        self.meter.reset_peak();
        self.meter.reset_step();

        // untimed: raw sampled-pair count (paper's auxiliary metric) —
        // fused native kernels count inline; other paths recount here
        t.pairs = match out.pairs {
            Some(p) => p,
            None => match self.cfg.variant {
                Variant::Dgl => sampler::block_sampled_pairs(
                    prepared.block.as_ref().unwrap()),
                Variant::Fsa => sampler::fused_sampled_pairs(
                    &self.ds.graph, &prepared.seeds, &self.cfg.fanouts,
                    prepared.base),
            },
        };

        self.step_count += 1;
        Ok(t)
    }

    /// Current parameters as host f32 tensors (canonical spec order).
    pub fn params_f32(&self) -> Result<Vec<Vec<f32>>> {
        self.backend.params_f32()
    }

    // ---------------------------------------------------------------
    // forward-only inference (the eval + serve path)
    // ---------------------------------------------------------------

    /// Base seed of every forward-only pass: the fixed eval schedule,
    /// independent of `step_count`. Combined with the per-node counter
    /// RNG this makes per-seed logits a pure function of (params, node)
    /// — invariant to batch grouping and arrival order.
    pub fn infer_base(&self) -> u64 {
        mix(self.cfg.seed ^ 0xEAE1)
    }

    /// Forward-only logits for `seeds` (row-major `[seeds.len(), c]`).
    /// `Ok(None)` when the backend has no forward-only path (PJRT AOT
    /// artifacts evaluate through [`evaluate_params`] instead).
    fn try_infer(&mut self, seeds: &[i32]) -> Result<Option<Vec<f32>>> {
        let n = self.ds.spec.n;
        for &s in seeds {
            ensure!(s >= 0 && (s as usize) < n,
                    "seed {s} out of range: dataset {:?} has nodes 0..{n}",
                    self.cfg.dataset);
        }
        let base = self.infer_base();
        let c = self.ds.spec.c;
        let mut out = Vec::with_capacity(seeds.len() * c);
        for chunk in seeds.chunks(INFER_CHUNK) {
            match self.backend.eval_logits(chunk, base)? {
                Some(logits) => {
                    ensure!(logits.len() == chunk.len() * c,
                            "backend returned {} logits for {} seeds \
                             x {c} classes", logits.len(), chunk.len());
                    out.extend_from_slice(&logits);
                }
                None => return Ok(None),
            }
        }
        Ok(Some(out))
    }

    /// Forward-only logits for `seeds`, or a hard error on backends
    /// without an ad-hoc forward path. This is the serving entry point.
    pub fn infer(&mut self, seeds: &[i32]) -> Result<Vec<f32>> {
        match self.try_infer(seeds)? {
            Some(logits) => Ok(logits),
            None => bail!("the {} backend has no forward-only inference \
                           path for ad-hoc seed sets; use --backend \
                           native", self.backend.name()),
        }
    }

    /// Measured shard-imbalance ratio of the most recent forward pass
    /// (None when it ran serially or on a backend that does not shard).
    pub fn infer_imbalance(&self) -> Option<f64> {
        self.backend.eval_imbalance()
    }

    /// Cumulative hub-cache `(hits, misses, refreshes)` counters since
    /// backend construction (`None` when `--hub-cache off` or the
    /// backend has no cache). Snapshot before/after a window and
    /// difference for per-window activity.
    pub fn hub_counters(&self) -> Option<(u64, u64, u64)> {
        self.backend.hub_counters()
    }

    /// Validation accuracy: the depth-matched eval forward at the
    /// 15-10(-5…) fanout over at least 512 val nodes, computed over
    /// [`Engine::infer`]'s logits. Backends without a forward-only path
    /// (PJRT) fall back to the dataset's `{fsa2|dgl2}_eval_*` artifact
    /// via [`evaluate_params`] — at depth 2 the two protocols coincide,
    /// so numbers are comparable across the backend seam; at depth 1 the
    /// native baseline is a different (single-layer) model than the
    /// fixed two-layer dgl1 artifacts, and at depth ≥ 3 only the native
    /// path exists (ROADMAP).
    pub fn evaluate(&mut self, max_nodes: usize) -> Result<f64> {
        let mut nodes = self.ds.split_nodes(Split::Val);
        nodes.truncate(max_nodes.max(512));
        let Some(logits) = self.try_infer(&nodes)? else {
            // backend has no forward-only path: AOT eval artifact
            return evaluate_params(self.rt, &self.ds, self.cfg.variant,
                                   &self.backend.params_f32()?,
                                   self.cfg.seed, max_nodes);
        };
        let c = self.ds.spec.c;
        let mut correct = 0usize;
        for (i, &u) in nodes.iter().enumerate() {
            if argmax(&logits[i * c..(i + 1) * c]) as i32
                == self.ds.labels[u as usize]
            {
                correct += 1;
            }
        }
        Ok(correct as f64 / nodes.len().max(1) as f64)
    }

    // ---------------------------------------------------------------
    // parameter checkpoints
    // ---------------------------------------------------------------

    /// Snapshot the current parameters with this session's identity.
    /// Backends that expose optimizer state (native) also snapshot the
    /// AdamW moments and step cursor, making the checkpoint resumable
    /// (v2 `train` block); others write a params-only file.
    pub fn params_checkpoint(&self) -> Result<ParamsCheckpoint> {
        Ok(ParamsCheckpoint {
            variant: self.cfg.variant.as_str().to_string(),
            dataset: self.cfg.dataset.clone(),
            fanout: self.cfg.fanouts.label(),
            hidden: self.rt.manifest.hidden,
            params: self.backend.params_f32()?,
            train: self.backend.opt_state_f32().map(|(m, v)| TrainState {
                step: self.step_count as u64,
                m,
                v,
            }),
        })
    }

    /// `fsa train --save-params` / `--checkpoint-every`: write a
    /// versioned checkpoint atomically, retrying transient failures
    /// with jittered exponential backoff. Exhausting the budget is a
    /// hard error naming the site.
    pub fn save_params(&self, path: &Path) -> Result<()> {
        let ck = self.params_checkpoint()?;
        let plane = self.cfg.faults.clone();
        let (res, retries) = faults::with_retries(
            FaultSite::CheckpointWrite, 3, self.cfg.seed,
            self.step_count as u64, || {
                let op = plane.begin(FaultSite::CheckpointWrite);
                faults::inject(plane.as_ref(), FaultSite::CheckpointWrite,
                               op)?;
                ck.save(path)
            });
        self.retries.set(self.retries.get() + retries as u64);
        res
    }

    /// Bounded-backoff retries persistence has consumed this session
    /// (the serving.csv `retries` column).
    pub fn retries_total(&self) -> u64 {
        self.retries.get()
    }

    /// Read + decode a checkpoint, routing the raw bytes through the
    /// fault plane: chaos `ckpt-read=corrupt` mangles them between read
    /// and parse, exactly where a torn disk would.
    fn read_checkpoint(&self, path: &Path) -> Result<ParamsCheckpoint> {
        let mut bytes = std::fs::read(path).with_context(|| {
            format!("reading params checkpoint {}", path.display())
        })?;
        let op = self.cfg.faults.begin(FaultSite::CheckpointRead);
        self.cfg.faults.mangle(FaultSite::CheckpointRead, op, &mut bytes);
        ParamsCheckpoint::parse_str(&String::from_utf8_lossy(&bytes), path)
    }

    /// `fsa serve --params`: load a checkpoint into the live backend.
    /// Any mismatch — variant, dataset, tensor count or shape, corrupt
    /// file — is a hard error; serving never silently falls back to
    /// fresh weights.
    pub fn load_params(&mut self, path: &Path) -> Result<()> {
        let ckpt = self.read_checkpoint(path)?;
        self.restore_checkpoint(&ckpt)
    }

    /// `fsa train --resume`: restore params **and** training state
    /// (AdamW moments, step cursor) from a v2 checkpoint, then
    /// fast-forward the batch schedule so step `k` resumes with exactly
    /// the seeds and base seed the uninterrupted run would have used at
    /// step `k`. Returns the restored step count. Must be called on a
    /// fresh session (before any steps).
    pub fn restore_training(&mut self, path: &Path) -> Result<usize> {
        let ckpt = self.read_checkpoint(path)?;
        let Some(train) = &ckpt.train else {
            bail!("checkpoint {} has no training state (a version-1 or \
                   params-only file); cannot --resume from it",
                  path.display());
        };
        ensure!(self.step_count == 0,
                "--resume must restore into a fresh session (already at \
                 step {})", self.step_count);
        // params first: set_params_f32 zeroes the moments
        self.restore_checkpoint(&ckpt)?;
        self.backend.set_opt_state_f32(&train.m, &train.v)?;
        let step = train.step as usize;
        // replay the scheduler: its state is a pure function of the
        // draw count, so `step` draws put the epoch/shuffle cursor
        // exactly where the uninterrupted run had it
        for _ in 0..step {
            let _ = self.sched.next_seeds();
        }
        self.step_count = step;
        Ok(step)
    }

    /// Restore an already-decoded checkpoint (identity checks + backend
    /// shape checks).
    pub fn restore_checkpoint(&mut self, ckpt: &ParamsCheckpoint)
                              -> Result<()> {
        ensure!(ckpt.variant == self.cfg.variant.as_str(),
                "checkpoint holds {:?} parameters but this session runs \
                 the {:?} variant", ckpt.variant, self.cfg.variant.as_str());
        ensure!(ckpt.dataset == self.cfg.dataset,
                "checkpoint was trained on dataset {:?} but this session \
                 serves {:?}", ckpt.dataset, self.cfg.dataset);
        self.backend.set_params_f32(&ckpt.params)
    }
}

impl Drop for Engine<'_> {
    /// "Saved at shutdown": persist the adaptive weights when the
    /// session ends *cleanly*. No-op unless `cfg.planner_state` is set,
    /// the flavor is adaptive, and feedback was observed. A panicking
    /// unwind deliberately skips the save — state measured up to an
    /// undefined failure point must not overwrite the last good file
    /// (pinned in `rust/tests/faults.rs`).
    fn drop(&mut self) {
        if std::thread::panicking() {
            return;
        }
        self.save_planner_state();
    }
}

/// Index of the max logit (ties break to the lower index).
pub fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Validation accuracy of a parameter set using the dataset's
/// `{fsa2|dgl2}_eval_*` artifact. Static graph/feature buffers come from
/// the runtime's per-dataset cache ([`Runtime::graph_bufs`]) instead of
/// being re-uploaded per call.
pub fn evaluate_params(rt: &Runtime, ds: &Dataset, variant: Variant,
                       params: &[Vec<f32>], seed: u64,
                       max_nodes: usize) -> Result<f64> {
    let name = format!("{}2_eval_{}_f15x10_b512", variant.as_str(),
                       ds.spec.name);
    let exe = rt.load(&name)?;
    let (b, k1, k2) = (exe.spec.batch, exe.spec.k1, exe.spec.k2);
    let np = exe.spec.n_params();
    anyhow::ensure!(params.len() == np,
                    "eval artifact {name} wants {np} params, got {}",
                    params.len());
    let mut nodes = ds.split_nodes(Split::Val);
    nodes.truncate(max_nodes.max(b));
    let eval_base = mix(seed ^ 0xEAE1);
    let x = rt.features_f32(ds)?;

    let mut correct = 0usize;
    let mut total = 0usize;
    for chunk in nodes.chunks(b) {
        let mut seeds = chunk.to_vec();
        let real = seeds.len();
        seeds.resize(b, chunk[0]); // pad; padded rows ignored below
        let mut owned: Vec<xla::PjRtBuffer> = Vec::with_capacity(10);
        for (vals, spec) in params.iter().zip(&exe.spec.inputs[..np]) {
            owned.push(rt.buf_f32(vals, &spec.shape)?);
        }
        let out = match variant {
            Variant::Fsa => {
                let graph = rt.graph_bufs(ds)?;
                owned.push(rt.buf_i32(&seeds, &[b])?);
                owned.push(rt.buf_u64(&[eval_base], &[1])?);
                let mut args: Vec<&xla::PjRtBuffer> =
                    owned[..np].iter().collect();
                args.push(&graph.rowptr);
                args.push(&graph.col);
                args.push(x.as_ref());
                args.push(&owned[np]);
                args.push(&owned[np + 1]);
                exe.run(&args)?
            }
            Variant::Dgl => {
                let fo = Fanouts::new(vec![k1, k2])?;
                let blk = sampler::build_block(&ds.graph, &seeds, &fo,
                                               eval_base);
                owned.push(rt.buf_i32(&blk.frontiers[1], &[b, 1 + k1])?);
                owned.push(rt.buf_i32(&blk.leaf, &[b, 1 + k1, k2])?);
                let mut args: Vec<&xla::PjRtBuffer> =
                    owned[..np].iter().collect();
                args.push(x.as_ref());
                args.push(&owned[np]);
                args.push(&owned[np + 1]);
                exe.run(&args)?
            }
        };
        let logits = out[0].to_vec::<f32>()?;
        let c = ds.spec.c;
        for (i, &u) in chunk.iter().enumerate().take(real) {
            let row = &logits[i * c..(i + 1) * c];
            if argmax(row) as i32 == ds.labels[u as usize] {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}
