//! Versioned parameter checkpoints (`fsa train --save-params` /
//! `fsa serve --params`).
//!
//! The on-disk format is the in-crate JSON (no serde in this build
//! environment): a single object carrying a format version, a kind tag,
//! the session identity (variant / dataset / fanout / hidden width), and
//! the parameter tensors in canonical spec order. f32 values are written
//! through f64 — an exact widening — and the writer emits shortest
//! round-trip decimals, so save → load is bitwise for every finite f32.
//!
//! Unlike the planner-state file ([`crate::graph::state`]), which
//! degrades to defaults on corruption because stale shard weights only
//! cost balance, a damaged params file would silently serve a *wrong
//! model* — so every load failure here is a hard error with the path and
//! the reason, pinned by the fuzz battery below.
//!
//! Version 2 adds an optional [`TrainState`] (step cursor + AdamW
//! moments) for crash-exact `fsa train --resume`: restoring params +
//! moments + the step count reproduces the uninterrupted loss
//! trajectory bitwise, because the sampling schedule is a pure function
//! of `(seed, step)`. Version-1 files still load (params only) but
//! cannot seed a resume. Files are written through
//! [`crate::util::atomic_write`], so a crash mid-save leaves the
//! previous complete checkpoint, never a torn one.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, ensure, Context, Result};

use crate::graph::state::unix_now;
use crate::json::Value;

/// Format version; bump on any incompatible layout change. Version 1
/// (params-only) files are still accepted by the loader.
pub const PARAMS_VERSION: u64 = 2;

/// Oldest version the loader still accepts.
pub const PARAMS_VERSION_MIN: u64 = 1;

/// Kind tag distinguishing this file from the other JSON state files
/// (planner state, manifests) a user might point `--params` at.
pub const PARAMS_KIND: &str = "fsa-params";

/// One saved parameter set plus the session identity it belongs to.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamsCheckpoint {
    /// Trainer variant ("fsa" | "dgl") — the tensors of one are
    /// meaningless under the other's forward.
    pub variant: String,
    /// Dataset name; features/classes must match at load time.
    pub dataset: String,
    /// Fanout label (e.g. "15x10") the model was trained under. Depth
    /// determines the tensor count, so this is identity, not metadata.
    pub fanout: String,
    /// Hidden width the tensor shapes were built for.
    pub hidden: usize,
    /// Parameter tensors in canonical spec order (row-major f32).
    pub params: Vec<Vec<f32>>,
    /// Optimizer + schedule state for crash-exact resume (None in
    /// legacy v1 files and final `--save-params` snapshots that only
    /// need to serve).
    pub train: Option<TrainState>,
}

/// The training-loop state a resume needs beyond the parameters: the
/// step cursor rebuilds the RNG/batch schedule (a pure function of
/// `(seed, step)`) and the AdamW bias correction; the moments make the
/// next update bitwise identical to the uninterrupted run's.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainState {
    /// Optimizer steps fully applied — the next step to run.
    pub step: u64,
    /// AdamW first moments, aligned with `params`.
    pub m: Vec<Vec<f32>>,
    /// AdamW second moments, aligned with `params`.
    pub v: Vec<Vec<f32>>,
}

/// Encode a tensor list as nested JSON arrays (f32 through exact f64
/// widening; the writer's shortest round-trip decimals make save → load
/// bitwise).
fn tensors_to_json(tensors: &[Vec<f32>]) -> Value {
    Value::Arr(tensors
        .iter()
        .map(|t| Value::Arr(
            t.iter().map(|&v| Value::Num(v as f64)).collect()))
        .collect())
}

/// Strict tensor-list decode; `what` names the field in errors.
fn tensors_from_json(value: &Value, what: &str)
                     -> std::result::Result<Vec<Vec<f32>>, String> {
    let raw = value
        .as_arr()
        .ok_or(format!("{what} is not an array"))?;
    if raw.is_empty() {
        return Err(format!("{what} array is empty"));
    }
    let mut out = Vec::with_capacity(raw.len());
    for (i, t) in raw.iter().enumerate() {
        let vals = t
            .as_arr()
            .ok_or(format!("{what}[{i}] is not an array"))?;
        if vals.is_empty() {
            return Err(format!("{what}[{i}] is empty"));
        }
        let mut tensor = Vec::with_capacity(vals.len());
        for (j, v) in vals.iter().enumerate() {
            let x = v
                .as_f64()
                .ok_or(format!("{what}[{i}][{j}] is not a number"))?
                as f32;
            if !x.is_finite() {
                return Err(format!("{what}[{i}][{j}] is not a finite f32"));
            }
            tensor.push(x);
        }
        out.push(tensor);
    }
    Ok(out)
}

/// Finiteness gate shared by the save path (`what` names the field).
fn ensure_finite(tensors: &[Vec<f32>], what: &str) -> Result<()> {
    for (i, t) in tensors.iter().enumerate() {
        ensure!(!t.is_empty(), "refusing to save: {what}[{i}] is empty");
        for (j, v) in t.iter().enumerate() {
            ensure!(v.is_finite(),
                    "refusing to save: {what}[{i}][{j}] is non-finite \
                     ({v}) — the model has diverged");
        }
    }
    Ok(())
}

impl ParamsCheckpoint {
    /// Serialize to a JSON value. Caller must have validated finiteness
    /// (`save` does): NaN/Inf have no JSON encoding.
    pub fn to_json(&self) -> Value {
        let mut root = BTreeMap::new();
        root.insert("version".into(), Value::Num(PARAMS_VERSION as f64));
        root.insert("kind".into(), Value::Str(PARAMS_KIND.into()));
        root.insert("variant".into(), Value::Str(self.variant.clone()));
        root.insert("dataset".into(), Value::Str(self.dataset.clone()));
        root.insert("fanout".into(), Value::Str(self.fanout.clone()));
        root.insert("hidden".into(), Value::Num(self.hidden as f64));
        root.insert("saved_unix".into(), Value::Num(unix_now() as f64));
        root.insert("params".into(), tensors_to_json(&self.params));
        if let Some(ts) = &self.train {
            let mut t = BTreeMap::new();
            t.insert("step".into(), Value::Num(ts.step as f64));
            t.insert("m".into(), tensors_to_json(&ts.m));
            t.insert("v".into(), tensors_to_json(&ts.v));
            root.insert("train".into(), Value::Obj(t));
        }
        Value::Obj(root)
    }

    /// Write to `path` atomically (tmp + fsync + rename), creating parent
    /// directories. Refuses non-finite parameters or moments — a
    /// diverged model must fail loudly at save time, not produce a file
    /// that fails to parse at serve time.
    pub fn save(&self, path: &Path) -> Result<()> {
        ensure!(!self.params.is_empty(), "refusing to save a checkpoint \
                                          with no parameter tensors");
        ensure_finite(&self.params, "params")?;
        if let Some(ts) = &self.train {
            ensure!(ts.m.len() == self.params.len()
                        && ts.v.len() == self.params.len(),
                    "refusing to save: train state has {}/{} moment \
                     tensors for {} params",
                    ts.m.len(), ts.v.len(), self.params.len());
            ensure_finite(&ts.m, "train.m")?;
            ensure_finite(&ts.v, "train.v")?;
        }
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).with_context(
                    || format!("creating {}", dir.display()))?;
            }
        }
        crate::util::atomic_write(path,
                                  format!("{}\n", self.to_json()).as_bytes())
            .with_context(|| format!("writing params checkpoint {}",
                                     path.display()))
    }

    /// Load from `path`. Every failure mode — missing file, truncated or
    /// garbage JSON, wrong version/kind, malformed tensors, non-finite
    /// values — is a hard error naming the path and the defect.
    pub fn load(path: &Path) -> Result<ParamsCheckpoint> {
        let text = std::fs::read_to_string(path).with_context(
            || format!("reading params checkpoint {}", path.display()))?;
        Self::parse_str(&text, path)
    }

    /// Decode checkpoint text read from `path` (split out so the chaos
    /// plane can corrupt the bytes between read and parse).
    pub fn parse_str(text: &str, path: &Path) -> Result<ParamsCheckpoint> {
        let value = crate::json::parse(text).map_err(
            |e| anyhow!("params checkpoint {} is not valid JSON ({e})",
                        path.display()))?;
        Self::from_json(&value).map_err(
            |msg| anyhow!("params checkpoint {}: {msg}", path.display()))
    }

    /// Strict decode; the `Err` string names the first defect found.
    pub fn from_json(value: &Value)
                     -> std::result::Result<ParamsCheckpoint, String> {
        let version = value
            .get("version")
            .and_then(Value::as_u64)
            .ok_or("missing or non-integer version field")?;
        if !(PARAMS_VERSION_MIN..=PARAMS_VERSION).contains(&version) {
            return Err(format!(
                "format version {version} is not the supported \
                 {PARAMS_VERSION_MIN}..={PARAMS_VERSION}"));
        }
        let kind = value
            .get("kind")
            .and_then(Value::as_str)
            .ok_or("missing kind field")?;
        if kind != PARAMS_KIND {
            return Err(format!(
                "kind {kind:?} is not {PARAMS_KIND:?} — wrong file?"));
        }
        let field = |k: &'static str| {
            value
                .get(k)
                .and_then(Value::as_str)
                .map(str::to_string)
                .ok_or(format!("missing or non-string {k} field"))
        };
        let variant = field("variant")?;
        let dataset = field("dataset")?;
        let fanout = field("fanout")?;
        let hidden = value
            .get("hidden")
            .and_then(Value::as_usize)
            .ok_or("missing or malformed hidden field")?;
        let params = tensors_from_json(
            value.get("params").ok_or("missing or non-array params field")?,
            "params")?;
        let train = match value.get("train") {
            None => None,
            Some(_) if version < 2 => {
                return Err("train state in a version-1 file".into());
            }
            Some(t) => {
                let step = t
                    .get("step")
                    .and_then(Value::as_u64)
                    .ok_or("missing or non-integer train.step field")?;
                let m = tensors_from_json(
                    t.get("m").ok_or("missing train.m field")?, "train.m")?;
                let v = tensors_from_json(
                    t.get("v").ok_or("missing train.v field")?, "train.v")?;
                if m.len() != params.len() || v.len() != params.len() {
                    return Err(format!(
                        "train state has {}/{} moment tensors for {} \
                         params", m.len(), v.len(), params.len()));
                }
                for (i, (mt, vt)) in m.iter().zip(&v).enumerate() {
                    if mt.len() != params[i].len()
                        || vt.len() != params[i].len() {
                        return Err(format!(
                            "train moment tensor {i} does not match \
                             params[{i}]'s length"));
                    }
                }
                Some(TrainState { step, m, v })
            }
        };
        Ok(ParamsCheckpoint { variant, dataset, fanout, hidden, params,
                              train })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("fsa_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample() -> ParamsCheckpoint {
        ParamsCheckpoint {
            variant: "fsa".into(),
            dataset: "tiny".into(),
            fanout: "5x3".into(),
            hidden: 32,
            params: vec![
                vec![1.0, -2.5, 3.25e-4, f32::MIN_POSITIVE, 0.1],
                vec![0.0, -0.0, f32::MAX, -1.0e-38, 7.0],
            ],
            train: None,
        }
    }

    /// save → load is bitwise for every finite f32 (the writer goes
    /// through exact f64 widening + shortest round-trip decimals).
    #[test]
    fn round_trip_is_bitwise() {
        let ckpt = sample();
        let p = tmp("round_trip.json");
        ckpt.save(&p).unwrap();
        let back = ParamsCheckpoint::load(&p).unwrap();
        assert_eq!(back, ckpt);
        for (a, b) in ckpt.params.iter().zip(&back.params) {
            for (&x, &y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "{x} vs {y}");
            }
        }
    }

    /// v2 train state (step + moments) round-trips bitwise alongside the
    /// params.
    #[test]
    fn train_state_round_trips_bitwise() {
        let mut ckpt = sample();
        ckpt.train = Some(TrainState {
            step: 17,
            m: vec![vec![0.5, -1.0e-9, 2.0, 0.0, 3.0],
                    vec![1.0, 2.0, 3.0, 4.0, 5.0]],
            v: vec![vec![1e-12, 0.25, 0.0, 7.5, 0.125],
                    vec![0.1, 0.2, 0.3, 0.4, 0.5]],
        });
        let p = tmp("train_round_trip.json");
        ckpt.save(&p).unwrap();
        let back = ParamsCheckpoint::load(&p).unwrap();
        assert_eq!(back, ckpt);
        assert_eq!(back.train.as_ref().unwrap().step, 17);
    }

    /// Legacy version-1 files (no train state) still load as
    /// params-only checkpoints.
    #[test]
    fn legacy_v1_files_load_without_train_state() {
        let v1 = r#"{"version":1,"kind":"fsa-params","variant":"fsa",
                     "dataset":"tiny","fanout":"5x3","hidden":32,
                     "params":[[1.0,2.0]]}"#;
        let p = tmp("legacy_v1.json");
        std::fs::write(&p, v1).unwrap();
        let ck = ParamsCheckpoint::load(&p).unwrap();
        assert_eq!(ck.params, vec![vec![1.0, 2.0]]);
        assert!(ck.train.is_none());
    }

    /// Malformed train state is a hard error, like every other defect.
    #[test]
    fn corrupt_train_state_is_a_hard_error() {
        let cases: &[(&str, &str)] = &[
            (r#"{"version":2,"kind":"fsa-params","variant":"fsa",
                 "dataset":"tiny","fanout":"5x3","hidden":32,
                 "params":[[1.0]],"train":{"m":[[0.0]],"v":[[0.0]]}}"#,
             "train.step"),
            (r#"{"version":2,"kind":"fsa-params","variant":"fsa",
                 "dataset":"tiny","fanout":"5x3","hidden":32,
                 "params":[[1.0]],"train":{"step":3,"m":[[0.0,1.0]],
                 "v":[[0.0,1.0]]}}"#,
             "does not match"),
            (r#"{"version":2,"kind":"fsa-params","variant":"fsa",
                 "dataset":"tiny","fanout":"5x3","hidden":32,
                 "params":[[1.0]],"train":{"step":3,"m":[[1e300]],
                 "v":[[0.0]]}}"#,
             "finite"),
            (r#"{"version":1,"kind":"fsa-params","variant":"fsa",
                 "dataset":"tiny","fanout":"5x3","hidden":32,
                 "params":[[1.0]],"train":{"step":3,"m":[[0.0]],
                 "v":[[0.0]]}}"#,
             "version-1"),
        ];
        for (text, needle) in cases {
            let err = ParamsCheckpoint::from_json(
                &crate::json::parse(text).unwrap())
                .expect_err(needle);
            assert!(err.contains(needle), "{err:?} missing {needle:?}");
        }
    }

    /// Awkward f32 values survive the decimal round trip bit-exactly.
    #[test]
    fn round_trip_survives_awkward_floats() {
        let mut r = crate::rng::SplitMix64::new(5);
        let vals: Vec<f32> = (0..512)
            .map(|_| (r.next_normal() * 1e3_f64.powf(r.next_f64() * 2.0
                                                     - 1.0)) as f32)
            .filter(|v| v.is_finite())
            .collect();
        let ckpt = ParamsCheckpoint { params: vec![vals], ..sample() };
        let p = tmp("awkward.json");
        ckpt.save(&p).unwrap();
        assert_eq!(ParamsCheckpoint::load(&p).unwrap(), ckpt);
    }

    /// Fuzz battery mirroring the planner-state one in
    /// `graph/state.rs` — but every case here must be a *hard error*
    /// (serve refuses to run a wrong model) rather than a silent
    /// degrade-to-defaults.
    #[test]
    fn corrupt_files_are_hard_errors() {
        let good = r#"{"version":1,"kind":"fsa-params","variant":"fsa",
                       "dataset":"tiny","fanout":"5x3","hidden":32,
                       "params":[[1.0,2.0]]}"#;
        assert!(ParamsCheckpoint::from_json(
            &crate::json::parse(good).unwrap()).is_ok());
        let cases: &[(&str, &[u8], &str)] = &[
            ("truncated",
             br#"{"version":1,"kind":"fsa-params","params":[[0.1"#,
             "JSON"),
            ("garbage", b"not json at all", "JSON"),
            ("empty", b"", "JSON"),
            ("binary", &[0xFF, 0x00, 0x92, 0x13], "JSON"),
            ("root_array", b"[1,2,3]", "version"),
            ("no_version",
             br#"{"kind":"fsa-params","params":[[1.0]]}"#,
             "version"),
            ("version_string",
             br#"{"version":"1","kind":"fsa-params","params":[[1.0]]}"#,
             "version"),
            ("wrong_version",
             br#"{"version":999,"kind":"fsa-params","params":[[1.0]]}"#,
             "version 999"),
            ("no_kind",
             br#"{"version":1,"variant":"fsa","params":[[1.0]]}"#,
             "kind"),
            ("wrong_kind",
             br#"{"version":1,"kind":"planner-state","params":[[1.0]]}"#,
             "wrong file"),
            ("no_params",
             br#"{"version":1,"kind":"fsa-params","variant":"fsa",
                 "dataset":"tiny","fanout":"5x3","hidden":32}"#,
             "params"),
            ("params_not_array",
             br#"{"version":1,"kind":"fsa-params","variant":"fsa",
                 "dataset":"tiny","fanout":"5x3","hidden":32,
                 "params":7}"#,
             "params"),
            ("params_empty",
             br#"{"version":1,"kind":"fsa-params","variant":"fsa",
                 "dataset":"tiny","fanout":"5x3","hidden":32,
                 "params":[]}"#,
             "empty"),
            ("tensor_not_array",
             br#"{"version":1,"kind":"fsa-params","variant":"fsa",
                 "dataset":"tiny","fanout":"5x3","hidden":32,
                 "params":[1,2]}"#,
             "params[0]"),
            ("tensor_holds_string",
             br#"{"version":1,"kind":"fsa-params","variant":"fsa",
                 "dataset":"tiny","fanout":"5x3","hidden":32,
                 "params":[[1.0,"x"]]}"#,
             "params[0][1]"),
            ("overflows_f32",
             br#"{"version":1,"kind":"fsa-params","variant":"fsa",
                 "dataset":"tiny","fanout":"5x3","hidden":32,
                 "params":[[1e300]]}"#,
             "finite"),
        ];
        for (name, bytes, needle) in cases {
            let p = tmp(&format!("corrupt_{name}.json"));
            std::fs::write(&p, bytes).unwrap();
            let err = ParamsCheckpoint::load(&p)
                .expect_err(&format!("{name} must not load"))
                .to_string();
            assert!(err.to_lowercase().contains(&needle.to_lowercase()),
                    "{name}: error {err:?} does not mention {needle:?}");
            assert!(err.contains("corrupt_"),
                    "{name}: error {err:?} does not name the file");
        }
        let missing = ParamsCheckpoint::load(&tmp("no_such_file.json"))
            .unwrap_err()
            .to_string();
        assert!(missing.contains("no_such_file"), "{missing}");
    }

    /// A diverged (NaN/Inf) model refuses to save instead of writing a
    /// file that cannot parse back.
    #[test]
    fn non_finite_params_refuse_to_save() {
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut ckpt = sample();
            ckpt.params[1][2] = bad;
            let err = ckpt
                .save(&tmp("nonfinite.json"))
                .unwrap_err()
                .to_string();
            assert!(err.contains("params[1][2]"), "{err}");
        }
        let empty = ParamsCheckpoint { params: vec![], ..sample() };
        assert!(empty.save(&tmp("empty_save.json")).is_err());
    }
}
