//! `fsa` — the FuseSampleAgg coordinator CLI.
//!
//! Subcommands:
//!   gen         generate a synthetic dataset, print shape statistics
//!   train       train one configuration, print per-step timings + loss
//!   serve       micro-batched online inference over a trained model
//!   bench-grid  run the paper's benchmark grid → results/bench.csv
//!   table       render a table/figure (1|2|fig1..fig5) from the CSV
//!   profile     stage-split baseline profile (Table 3)
//!   memory      analytic transient-memory model for a configuration
//!   throughput  host sampling/batch pipeline: steps/sec + utilization
//!   inspect     show manifest metadata for an artifact
//!
//! Fanouts are arbitrary-depth lists: `--fanout 10` (1-hop),
//! `--fanout 15x10` (2-hop), `--fanout 15x10x5` (3-hop), and so on.
//!
//! Examples:
//!   fsa train --variant fsa --dataset products_sim --fanout 15x10 \
//!       --batch 1024 --steps 30 --threads 4 --prefetch on
//!   fsa train --fanout 10x5x5 --backend native     # 3-hop, native engine
//!   fsa train --dataset arxiv_sim --workers 4      # data-parallel, bitwise
//!                                                  # equal to --workers 1
//!   fsa bench-grid --out results/bench.csv
//!   fsa table --which 1 --csv results/bench.csv
//!   fsa throughput --dataset arxiv_sim --sweep

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};
use fusesampleagg::bench::{self, render, throughput, Grid};
use fusesampleagg::cli::{self, Args};
use fusesampleagg::coordinator::{profile, DatasetCache, TrainConfig, Trainer,
                                 Variant};
use fusesampleagg::dist;
use fusesampleagg::engine::{argmax, Engine};
use fusesampleagg::fanout::Fanouts;
use fusesampleagg::gen::{builtin_spec, Dataset, Split};
use fusesampleagg::graph::PlannerChoice;
use fusesampleagg::kernel::{FeatureLayout, SimdChoice};
use fusesampleagg::memory::{self, StepDims};
use fusesampleagg::metrics;
use fusesampleagg::runtime::faults::{self, ChaosPlane, FaultPlane};
use fusesampleagg::runtime::{BackendChoice, Manifest, Runtime};
use fusesampleagg::serve;
use fusesampleagg::util;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "gen" => cmd_gen(args),
        "train" => cmd_train(args),
        "serve" => cmd_serve(args),
        "bench-grid" => cmd_bench_grid(args),
        "table" => cmd_table(args),
        "profile" => cmd_profile(args),
        "memory" => cmd_memory(args),
        "throughput" => cmd_throughput(args),
        "inspect" => cmd_inspect(args),
        // hidden child entrypoint of `fsa train --workers N` (its args
        // are an internal contract with dist::spawn_child, so it stays
        // out of the subcommand summary)
        "dist-worker" => cmd_dist_worker(args),
        "" | "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}; subcommands are:\n{}\
                        try `fsa help` for full usage",
                       cli::subcommand_summary()),
    }
}

fn print_help() {
    println!("fsa — FuseSampleAgg coordinator (rust+JAX+Pallas \
              reproduction)\n\nUSAGE: fsa <subcommand> [options]\n\n\
              SUBCOMMANDS\n{}", cli::subcommand_summary());
    print!("{}", HELP);
}

const HELP: &str = "\
OPTIONS PER SUBCOMMAND
  gen         --dataset NAME                       generate + print stats
  train       --variant fsa|dgl --dataset NAME --fanout K1xK2[xK3...]
              --batch B [--steps N] [--warmup N] [--seed S] [--no-amp]
              [--eval] [--threads N] [--prefetch on|off]
              [--backend auto|native|pjrt]
              [--planner nominal|quantile|adaptive]
              [--planner-state PATH|off] [--chaos SPEC]
              [--simd auto|on|off] [--layout natural|degree]
              [--hub-cache off|N]
              [--save-params FILE]   write a versioned params checkpoint
                                     at shutdown (for `fsa serve`)
              [--checkpoint-every N] also checkpoint every N steps
                                     (params + AdamW moments + step
                                     cursor, written atomically)
              [--resume]             restore params/opt-state/step from
                                     --save-params FILE and continue; the
                                     resumed loss trajectory is bitwise
                                     identical to the uninterrupted run
              [--workers N]          data-parallel over N localhost
                                     worker processes (fsa variant only).
                                     The loss trajectory is bitwise
                                     identical for any N at a matched
                                     config, and additionally identical
                                     to the plain single-process path
                                     when --micro-batch >= batch. A dead
                                     worker (detected by heartbeat) has
                                     its shard reassigned and its micros
                                     re-dispatched; the run completes on
                                     the survivors
              [--micro-batch M]      seeds per gradient micro-batch
                                     (default ceil(batch/4), clamped to
                                     the batch)
              [--heartbeat-ms MS]    worker liveness beacon period
                                     (default 500); silence past ~4x
                                     this marks a worker dead
              [--dist-out FILE]      per-worker session stats CSV
                                     (default results/dist.csv)
  serve       [--params FILE] [--dataset NAME] [--variant fsa|dgl]
              [--fanout K1xK2[...]] [--batch-window-ms X] [--max-batch N]
              [--queue-depth N] [--deadline-ms X] [--threads N]
              [--backend native] [--planner ...]
              [--planner-state PATH|off] [--seed S] [--chaos SPEC]
              [--simd auto|on|off] [--layout natural|degree]
              [--hub-cache off|N]
              reads one request per stdin line (space/comma-separated
              seed node ids), replies with argmax classes + latency;
              malformed lines get an `ERR <reason>` reply and the server
              keeps serving; unknown --options are rejected with a
              suggestion
              --bench   closed-loop load generator instead of stdin:
              [--rates R1,R2] [--windows W1,W2] [--duration-ms X]
              [--clients N] [--seeds-per-request N] [--out FILE]
              sweeps arrival rate x batch window -> serving.csv with
              p50/p95/p99 latency, shed counts, achieved rps
  bench-grid  [--quick] [--depths] [--datasets a,b]
              [--fanouts 10x10,15x10,15x10x5] [--batches 512,1024]
              [--steps N] [--warmup N] [--out FILE] [--threads N]
              [--prefetch on|off] [--backend auto|native|pjrt]
              [--planner nominal|quantile|adaptive]
              [--planner-state PATH|off]
              [--simd auto|on|off] [--layout natural|degree]
              [--hub-cache off|N]
  table       --which 1|2|3|fig1|fig2|fig3|fig4|fig5 [--csv FILE]
  profile     [--steps N] [--warmup N] [--seed S]      (Table 3)
  memory      --dataset NAME --fanout K1xK2[xK3...] --batch B
              (analytic model, any depth)
  throughput  --dataset NAME [--fanout K1xK2[xK3...]] [--batch B]
              [--steps N] [--threads N] [--prefetch on|off]
              [--dispatch-ms X] [--sweep] [--backend emulated|native]
              [--variant fsa|dgl] [--planner nominal|quantile|adaptive]
              [--simd auto|on|off] [--layout natural|degree]
              [--hub-cache off|N]
              host sampling/batch pipeline: steps/sec + shard imbalance
              + utilization (no artifacts needed; dispatch is emulated or
              native compute)
  inspect     --artifact NAME | --list

FANOUT SYNTAX
  One positive integer per hop, joined by 'x', '_' or ',':
  10 = 1-hop, 15x10 = 2-hop, 15x10x5 = 3-hop (SALIENT-style), any depth.
  The sampler, kernels, model depth, and eval protocol all follow the
  fanout list; nothing else selects the hop count.

BACKENDS
  --backend auto    (default) run the AOT/PJRT artifact when it compiles,
                    otherwise the native CPU engine — real host compute,
                    no artifacts required
  --backend native  always use the native engine (any fanout depth)
  --backend pjrt    require the AOT artifact (error when missing/stubbed;
                    the artifact manifest only defines depth <= 2)

PIPELINE KNOBS
  --threads N       host sampler + native-kernel worker threads (0 = auto,
                    default 1); output is bitwise identical at any value
  --prefetch on     overlap host sampling of step t+1 with dispatch of
                    step t (double-buffered; default off)
  --planner P       shard-planner cost model (default quantile):
                      nominal   legacy full-fanout subtree weights
                      quantile  degree-quantile expected-subtree costs
                      adaptive  quantile + measured per-shard throughput
                    outputs are bitwise identical under every flavor —
                    only shard balance (reported as the imbalance
                    column/ratio, max/mean worker ms) moves
  --planner-state   where the adaptive planner persists its measured
                    per-worker weights across sessions, keyed by
                    (host, threads, flavor). Default for `train`:
                    results/planner_state.json (warm-start on load,
                    save at shutdown); `off` disables. bench-grid
                    defaults to off so paper-protocol rows never
                    inherit another run's weights. Corrupt/mismatched
                    files fall back to uniform weights with a warning.
                    Adaptive cut positions may differ across sessions
                    because of this; sampled values never do.
  --simd S          native-kernel vector tier (default auto):
                      auto  use AVX2 gather/fold when the CPU has it
                            (FSA_SIMD=off|0 in the environment forces
                            the scalar tier without re-invoking)
                      on    force the vector tier
                      off   force the scalar reference tier
                    outputs are bitwise identical either way — SIMD
                    lanes run across the feature dimension, never
                    across neighbors, so no float op is reassociated;
                    only step time moves
  --layout L        feature-row storage order (default natural):
                      natural  rows stored in node-id order
                      degree   opt-in locality pass: rows permuted into
                               degree-descending order behind an index
                               map, so hot hub rows share cache lines
                    node ids, RNG draws, saved indices, and planner
                    costs are untouched — outputs are bitwise identical
  FSA_D_TILE=N      override the native feature-tile width (elements per
                    cache-blocked gather pass; default from detected L1d
                    geometry, rounded to the SIMD lane width). Any value
                    is bitwise-output-identical; `cargo bench --bench
                    tile_sweep` measures the sweet spot
  --hub-cache C     hub-aggregate cache on the native fused path
                    (default off):
                      off   no cache; the fused kernel gathers every
                            leaf subtree from scratch
                      N     cache the innermost-hop partial mean for
                            high-degree (hub) nodes, rebuilding at most
                            N entries per step. Entries are keyed by
                            (node, leaf fanout, seed epoch), so a hit
                            replays the exact neighbor draw the RNG
                            schedule would have produced — losses,
                            logits, gradients, and saved indices are
                            bitwise identical to `off` at every thread
                            count. Only step/serve time moves; wins are
                            largest on skewed (zipf/hubs) degree laws,
                            neutral on uniform ones.
                    FSA_HUB_CACHE=off|N in the environment overrides the
                    flag without re-invoking (used by CI to force the
                    cache on across the numeric suites)

FAULT INJECTION (--chaos, train/serve)
  Deterministic chaos for fault-tolerance testing; production runs
  (no --chaos) take the zero-cost no-op plane and are bitwise
  unaffected. Spec: rules separated by ';', each
      site@ops[/wN][~P]=kind
  with site  kernel|sampler|state-write|ckpt-write|ckpt-read|
             csv-write|serve|dist-send|dist-recv
       ops   N | N-M | *          (site-local operation counter)
       kind  panic|err|corrupt|stall:MS
  e.g. --chaos 'kernel@3/w1=panic; ckpt-write@*=err'. Same spec + seed
  replays the same fault schedule at any thread count.
";

fn backend_choice(args: &Args) -> Result<BackendChoice> {
    BackendChoice::parse(&args.str_or("backend", "auto"))
}

/// `--chaos SPEC`: the scripted fault plane, or the production no-op
/// plane when absent. Seeded from the run seed so a chaos schedule
/// replays with the run.
fn chaos_arg(args: &Args, seed: u64) -> Result<Arc<dyn FaultPlane>> {
    match args.str_opt("chaos") {
        Some(spec) => Ok(Arc::new(ChaosPlane::parse(spec, seed)?)),
        None => Ok(faults::none()),
    }
}

fn planner_choice(args: &Args) -> Result<PlannerChoice> {
    PlannerChoice::parse(&args.str_or("planner", "quantile"))
}

fn simd_choice(args: &Args) -> Result<SimdChoice> {
    SimdChoice::parse(&args.str_or("simd", "auto"))
}

fn layout_choice(args: &Args) -> Result<FeatureLayout> {
    FeatureLayout::parse(&args.str_or("layout", "natural"))
}

/// `--hub-cache off|N`: per-step refresh budget for the hub-aggregate
/// cache on the native fused path. `off` (the default) disables it; a
/// budget `N` caps how many hub entries may be (re)built per step.
/// Outputs are bitwise identical either way — only step time moves.
fn hub_cache_arg(args: &Args) -> Result<Option<usize>> {
    match args.str_opt("hub-cache") {
        None | Some("off") => Ok(None),
        Some(v) => v.parse::<usize>().map(Some).map_err(|_| {
            anyhow!("--hub-cache expects `off` or a refresh budget N, \
                     got {v:?}")
        }),
    }
}

/// `--planner-state <path|off>`: where the adaptive planner persists its
/// per-worker weights. Defaults to `results/planner_state.json`; `off`
/// disables persistence. Only the adaptive flavor reads/writes it.
fn planner_state_arg(args: &Args, planner: PlannerChoice)
                     -> Option<std::path::PathBuf> {
    match args.str_opt("planner-state") {
        Some("off") => None,
        Some(p) => Some(std::path::PathBuf::from(p)),
        // don't touch (or create) results/ unless the flavor has state
        None if planner == PlannerChoice::Adaptive => {
            Some(util::results_dir().join("planner_state.json"))
        }
        None => None,
    }
}

fn cmd_gen(args: &Args) -> Result<()> {
    let name = args.str_or("dataset", "tiny");
    let spec = builtin_spec(&name)?;
    let t = metrics::Timer::start();
    let ds = Dataset::generate(spec)?;
    let stats = ds.graph.degree_stats();
    println!("dataset {name} (stands for {}):", ds.spec.stands_for);
    println!("  nodes {}  edges {}  e_cap {}  ({:.1}ms to generate)",
             ds.spec.n, ds.graph.num_edges(), ds.graph.e_cap(), t.ms());
    println!("  degree: mean {:.1}  median {}  p99 {}  max {}  isolated {}",
             stats.mean, stats.median, stats.p99, stats.max, stats.isolated);
    println!("  features [{} x {}], {} classes", ds.spec.n, ds.spec.d,
             ds.spec.c);
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();
    let variant = match args.str_or("variant", "fsa").as_str() {
        "fsa" => Variant::Fsa,
        "dgl" => Variant::Dgl,
        v => bail!("--variant must be fsa|dgl, got {v:?}"),
    };
    let fanouts = args.fanout("fanout", &Fanouts::of(&[15, 10]))?;
    let planner = planner_choice(args)?;
    let seed = args.u64_or("seed", 42)?;
    let cfg = TrainConfig {
        variant,
        dataset: args.str_or("dataset", "products_sim"),
        fanouts,
        batch: args.usize_or("batch", 1024)?,
        amp: !args.has("no-amp"),
        save_indices: !args.has("no-save-indices"),
        seed,
        threads: args.usize_or("threads", 1)?,
        prefetch: args.bool_or("prefetch", false)?,
        backend: backend_choice(args)?,
        planner,
        planner_state: planner_state_arg(args, planner),
        faults: chaos_arg(args, seed)?,
        simd: simd_choice(args)?,
        layout: layout_choice(args)?,
        hub_cache: hub_cache_arg(args)?,
    };
    let steps = args.usize_or("steps", 30)?;
    let warmup = args.usize_or("warmup", 5)?;
    let ckpt_every = args.usize_or("checkpoint-every", 0)?;
    let ckpt_path = args.str_opt("save-params").map(std::path::PathBuf::from);
    if ckpt_every > 0 && ckpt_path.is_none() {
        bail!("--checkpoint-every needs --save-params FILE (the checkpoint \
               destination)");
    }
    if args.has("resume") && ckpt_path.is_none() {
        bail!("--resume needs --save-params FILE (the checkpoint to resume \
               from)");
    }

    println!("training {} on {} fanout {} ({}-hop) batch {} amp={} seed={} \
              threads={} prefetch={}",
             cfg.variant.as_str(), cfg.dataset, cfg.fanouts, cfg.hops(),
             cfg.batch, cfg.amp, cfg.seed, cfg.threads, cfg.prefetch);

    // --workers routes to the localhost data-parallel coordinator; the
    // single-process Trainer below never runs in that mode
    if let Some(w) = args.str_opt("workers") {
        let workers: usize = w.parse().map_err(|_| {
            anyhow!("--workers expects a worker count, got {w:?}")
        })?;
        let opts = dist::DistOptions {
            workers,
            micro_batch: args.usize_or("micro-batch", 0)?,
            heartbeat_ms: args.u64_or("heartbeat-ms", 500)?,
            mode: dist::WorkerMode::Process,
            steps,
            warmup,
            ckpt_every,
            ckpt_path: ckpt_path.clone(),
            resume: args.has("resume"),
            dist_out: Some(match args.str_opt("dist-out") {
                Some(p) => std::path::PathBuf::from(p),
                None => util::results_dir().join("dist.csv"),
            }),
        };
        println!("backend: native ({workers} dist worker processes)");
        let ds = cache.get(&rt, &cfg.dataset)?;
        let report = dist::train(ds, &cfg, rt.manifest.hidden,
                                 rt.manifest.adamw, &opts)?;
        let summary = metrics::summarize(&report.step_ms);
        println!("median step {:.2} ms  (p10 {:.2}, p90 {:.2}, n={})",
                 summary.median, summary.p10, summary.p90, summary.n);
        if args.has("eval") {
            eprintln!("note: --eval is not wired for --workers; load the \
                       --save-params checkpoint with `fsa serve` or a \
                       plain `fsa train --resume` run instead");
        }
        return Ok(());
    }

    let mut trainer = Trainer::new(&rt, &mut cache, cfg)?;
    println!("backend: {}", trainer.backend_name());
    // resumed sessions skip the warmup: the checkpoint's step cursor
    // already includes it, and replaying it would desync the schedule
    let mut start_s = 0usize;
    if args.has("resume") {
        let p = ckpt_path.as_deref().unwrap();
        let done = trainer.engine_mut().restore_training(p)?;
        anyhow::ensure!(done >= warmup,
                        "checkpoint {} is at step {done}, inside the \
                         {warmup}-step warmup; nothing to resume",
                        p.display());
        start_s = done - warmup;
        println!("resumed from {} at step {done} (timed step {start_s})",
                 p.display());
    } else {
        for _ in 0..warmup {
            trainer.step()?;
        }
    }
    let mut totals = Vec::new();
    let mut overlaps = Vec::new();
    let mut imbalances = Vec::new();
    for s in start_s..steps {
        let t = trainer.step()?;
        totals.push(t.total_ms());
        overlaps.push(t.sample_overlap_ms);
        imbalances.push(t.imbalance);
        if s % 10 == 0 || s == steps - 1 {
            println!("step {s:>4}: {:.2} ms (sample {:.2} upload {:.2} exec \
                      {:.2}) loss {:.4}",
                     t.total_ms(), t.sample_ms, t.upload_ms, t.execute_ms,
                     t.loss);
        }
        if ckpt_every > 0 && (s + 1) % ckpt_every == 0 {
            trainer.save_params(ckpt_path.as_deref().unwrap())?;
        }
    }
    let summary = metrics::summarize(&totals);
    println!("median step {:.2} ms  (p10 {:.2}, p90 {:.2}, n={})",
             summary.median, summary.p10, summary.p90, summary.n);
    if trainer.cfg.threads != 1 {
        println!("shard imbalance (max/mean worker ms, planner {}): \
                  median {:.2}",
                 trainer.cfg.planner.as_str(),
                 metrics::median(&imbalances));
    }
    if trainer.cfg.prefetch {
        println!("prefetch: median {:.2} ms of host sampling overlapped \
                  off the critical path",
                 metrics::median(&overlaps));
    }
    if args.has("eval") {
        let acc = trainer.evaluate(2048)?;
        println!("validation accuracy: {:.3}", acc);
    }
    if let Some(p) = args.str_opt("save-params") {
        trainer.save_params(Path::new(p))?;
        println!("saved params checkpoint to {p}");
    }
    Ok(())
}

/// Hidden child entrypoint of `fsa train --workers N`: rebuild the
/// dataset from its spec (generation is deterministic, so nothing
/// graph-sized crosses a pipe), connect back to the coordinator, and
/// serve gradient requests until `Shutdown` or EOF.
fn cmd_dist_worker(args: &Args) -> Result<()> {
    let addr = args
        .str_opt("connect")
        .context("dist-worker: --connect HOST:PORT required")?;
    let dataset = args.str_or("dataset", "tiny");
    let ds = Arc::new(Dataset::generate(builtin_spec(&dataset)?)?);
    let cfg = dist::worker::WorkerConfig {
        rank: args.usize_or("rank", 0)? as u32,
        ds,
        fanouts: args.fanout("fanout", &Fanouts::of(&[15, 10]))?,
        amp: !args.has("no-amp"),
        seed: args.u64_or("seed", 42)?,
        threads: args.usize_or("threads", 1)?,
        hidden: args.usize_or("hidden", Manifest::builtin().hidden)?,
        simd: simd_choice(args)?,
        layout: layout_choice(args)?,
        heartbeat_ms: args.u64_or("heartbeat-ms", 500)?,
    };
    dist::worker::connect_and_run(addr, cfg)
}

/// `--key X` as f64 with a default.
fn f64_opt(args: &Args, key: &str, default: f64) -> Result<f64> {
    match args.str_opt(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
    }
}

/// `--key X1,X2,...` as f64s with a default list.
fn f64_list(args: &Args, key: &str, default: &[f64]) -> Result<Vec<f64>> {
    match args.str_opt(key) {
        None => Ok(default.to_vec()),
        Some(v) => v
            .split(',')
            .map(|s| {
                s.trim().parse().map_err(|_| {
                    anyhow!("--{key} expects comma-separated numbers, \
                             got {s:?}")
                })
            })
            .collect(),
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    // serve rejects typos outright: a misspelled policy flag silently
    // falling back to its default is exactly the failure mode an online
    // service cannot afford
    const SERVE_OPTIONS: &[&str] = &[
        "dataset", "variant", "fanout", "params", "batch",
        "batch-window-ms", "max-batch", "queue-depth", "deadline-ms",
        "threads", "backend", "planner", "planner-state", "seed", "chaos",
        "simd", "layout", "hub-cache", "rates", "windows", "duration-ms",
        "clients", "seeds-per-request", "out",
    ];
    const SERVE_SWITCHES: &[&str] = &["bench", "no-amp"];
    args.ensure_known(SERVE_OPTIONS, SERVE_SWITCHES)?;

    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();
    let variant = match args.str_or("variant", "fsa").as_str() {
        "fsa" => Variant::Fsa,
        "dgl" => Variant::Dgl,
        v => bail!("--variant must be fsa|dgl, got {v:?}"),
    };
    let planner = planner_choice(args)?;
    let seed = args.u64_or("seed", 42)?;
    let cfg = TrainConfig {
        variant,
        dataset: args.str_or("dataset", "products_sim"),
        fanouts: args.fanout("fanout", &Fanouts::of(&[15, 10]))?,
        batch: args.usize_or("batch", 64)?,
        amp: !args.has("no-amp"),
        save_indices: false,
        seed,
        threads: args.usize_or("threads", 1)?,
        prefetch: false,
        backend: BackendChoice::parse(&args.str_or("backend", "native"))?,
        planner,
        planner_state: planner_state_arg(args, planner),
        faults: chaos_arg(args, seed)?,
        simd: simd_choice(args)?,
        layout: layout_choice(args)?,
        hub_cache: hub_cache_arg(args)?,
    };
    let scfg = serve::ServeConfig {
        batch_window_ms: f64_opt(args, "batch-window-ms", 2.0)?,
        max_batch: args.usize_or("max-batch", 512)?,
        queue_depth: args.usize_or("queue-depth", 64)?,
        deadline_ms: f64_opt(args, "deadline-ms", 0.0)?,
    };

    println!("serving {} on {} fanout {} ({}-hop) threads={} \
              window={}ms max-batch={} queue-depth={}",
             cfg.variant.as_str(), cfg.dataset, cfg.fanouts, cfg.hops(),
             cfg.threads, scfg.batch_window_ms, scfg.max_batch,
             scfg.queue_depth);
    let mut engine = Engine::new(&rt, &mut cache, cfg)?;
    println!("backend: {}", engine.backend_name());
    match args.str_opt("params") {
        Some(p) => {
            engine.load_params(Path::new(p))?;
            println!("loaded params checkpoint {p}");
        }
        None => eprintln!("note: no --params checkpoint; serving freshly \
                           initialized (untrained) parameters"),
    }

    // warm up the forward path before taking traffic: a full val-split
    // pass both JIT-warms caches and, at threads>1, gives the adaptive
    // planner a sharded measurement to learn from
    let t = metrics::Timer::start();
    let mut warm = engine.ds.split_nodes(Split::Val);
    warm.truncate(warm.len().min(128).max(1));
    engine.infer(&warm)?;
    println!("warmup: {} seeds in {:.1} ms", warm.len(), t.ms());

    if args.has("bench") {
        let bc = serve::bench::BenchConfig {
            rates: f64_list(args, "rates", &[200.0, 1000.0])?,
            windows_ms: f64_list(args, "windows", &[0.0, 2.0])?,
            duration_ms: f64_opt(args, "duration-ms", 1000.0)?,
            clients: args.usize_or("clients", 4)?,
            seeds_per_request: args.usize_or("seeds-per-request", 4)?,
            max_batch: scfg.max_batch,
            queue_depth: scfg.queue_depth,
            deadline_ms: scfg.deadline_ms,
            seed: args.u64_or("seed", 42)?,
        };
        let rows = serve::bench::run_bench(&mut engine, &bc)?;
        println!("\n{}", serve::bench::render_table(&rows));
        let out_path = match args.str_opt("out") {
            Some(p) => std::path::PathBuf::from(p),
            None => util::results_dir().join("serving.csv"),
        };
        metrics::write_serving_csv(&out_path, &rows)?;
        println!("wrote {} rows to {}", rows.len(), out_path.display());
        return Ok(());
    }

    // stdin line protocol: one request per line, seed ids separated by
    // spaces/commas/tabs; EOF (or closing the pipe) shuts down cleanly.
    // Malformed lines get a structured `ERR <reason>` reply and the
    // server keeps serving — bad input must never take the loop down.
    let (handle, rx) = serve::channel(&scfg, engine.ds.spec.n);
    let queue_depth = scfg.queue_depth;
    let reader = std::thread::spawn(move || {
        use std::io::BufRead as _;
        let stdin = std::io::stdin();
        for line in stdin.lock().lines() {
            let Ok(line) = line else { break };
            if line.trim().is_empty() {
                continue;
            }
            let seeds = match serve::parse_request_line(&line) {
                Ok(s) => s,
                Err(e) => {
                    println!("ERR {e}");
                    continue;
                }
            };
            match handle.submit(seeds.clone()) {
                Ok(serve::Submit::Accepted(reply)) => {
                    let Ok(r) = reply.recv() else { break };
                    match &r.body {
                        serve::ReplyBody::Scores(scores) => {
                            let c = scores.len() / seeds.len().max(1);
                            let classes: Vec<usize> = scores
                                .chunks(c.max(1))
                                .map(argmax)
                                .collect();
                            println!("seeds {seeds:?} -> classes \
                                      {classes:?} ({:.2} ms)",
                                     r.latency_ms);
                        }
                        serve::ReplyBody::Timeout => {
                            println!("ERR deadline exceeded \
                                      ({:.2} ms waited)", r.latency_ms);
                        }
                        serve::ReplyBody::Error(reason) => {
                            println!("ERR {reason}");
                        }
                    }
                }
                Ok(serve::Submit::Shed) => {
                    println!("ERR queue full \
                              (--queue-depth {queue_depth})");
                }
                Err(e) => {
                    println!("ERR {e}");
                }
            }
        }
        // dropping the handle lets the server loop drain and exit
    });
    let stats = serve::run_server(&mut engine, &scfg, &rx)?;
    reader.join().map_err(|_| anyhow!("stdin reader panicked"))?;
    let (p50, p95, p99) = stats.latency_percentiles();
    println!("served {} requests in {} micro-batches (mean {:.1} \
              seeds/batch); latency p50 {:.2} p95 {:.2} p99 {:.2} ms; \
              {} faulted, {} timed out, {} retries",
             stats.completed, stats.batches, stats.mean_batch_seeds(),
             p50, p95, p99, stats.faults, stats.timeouts, stats.retries);
    Ok(())
}

fn cmd_bench_grid(args: &Args) -> Result<()> {
    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();
    let mut grid = if args.has("quick") {
        Grid::quick()
    } else if args.has("depths") {
        // depth axis: 1/2/3 hops at a matched 150-leaf budget
        Grid::depth_axis()
    } else {
        Grid::default()
    };
    if let Some(ds) = args.str_opt("datasets") {
        grid.datasets = ds.split(',').map(|s| s.trim().to_string()).collect();
    }
    if let Some(f) = args.str_opt("fanouts") {
        // list entries use the 'x'/'_' separators ("10x10,15x10x5");
        // the ',' fanout form is for the single-value --fanout option
        grid.fanouts = f
            .split(',')
            .map(fusesampleagg::cli::parse_fanout)
            .collect::<Result<_>>()?;
        if grid.fanouts.len() > 1 && grid.fanouts.iter().all(|f| f.depth() == 1)
        {
            eprintln!("note: --fanouts {f:?} parsed as {} separate 1-hop \
                       grids ({}); for a single multi-hop fanout use 'x' \
                       separators (e.g. --fanouts 15x10), or --fanout for \
                       the comma form",
                      grid.fanouts.len(),
                      grid.fanouts
                          .iter()
                          .map(|f| f.label())
                          .collect::<Vec<_>>()
                          .join(", "));
        }
    }
    if let Some(b) = args.str_opt("batches") {
        grid.batches = b
            .split(',')
            .map(|s| s.trim().parse().context("bad batch"))
            .collect::<Result<_>>()?;
    }
    grid.steps = args.usize_or("steps", grid.steps)?;
    grid.warmup = args.usize_or("warmup", grid.warmup)?;
    grid.threads = args.usize_or("threads", grid.threads)?;
    grid.prefetch = args.bool_or("prefetch", grid.prefetch)?;
    grid.backend = backend_choice(args)?;
    grid.planner = planner_choice(args)?;
    grid.simd = simd_choice(args)?;
    grid.layout = layout_choice(args)?;
    grid.hub_cache = hub_cache_arg(args)?;
    // bench cells default to NO planner-state persistence (a
    // paper-protocol grid must not inherit another run's weights);
    // --planner-state <path> opts in explicitly
    grid.planner_state = match args.str_opt("planner-state") {
        Some("off") | None => None,
        Some(p) => Some(std::path::PathBuf::from(p)),
    };
    if grid.threads != 1 || grid.prefetch {
        eprintln!("note: --threads/--prefetch change step_ms/sample_ms \
                   semantics and the CSV schema does not record them — \
                   rows are NOT comparable with paper-protocol runs; use \
                   `fsa throughput` for pipeline scaling measurements");
    }

    let out_path = match args.str_opt("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => util::results_dir().join("bench.csv"),
    };
    let rows = bench::run_grid(&rt, &mut cache, &grid, |r| {
        println!("{:<14} {:<4} f{:<8} b{:<5} seed {}: {:>8.2} ms/step \
                  ({:.0} pairs/s, {:.1} MB transient)",
                 r.dataset, r.variant, r.fanout, r.batch, r.repeat_seed,
                 r.step_ms, r.pairs_per_s,
                 util::bytes_to_mb(r.peak_transient_bytes));
    })?;
    metrics::write_csv(&out_path, &rows)?;
    println!("wrote {} rows to {}", rows.len(), out_path.display());

    // An *explicit* `--backend native` run additionally emits the
    // fused-vs-baseline summary under results/. Auto runs do not (what
    // each cell resolved to isn't recorded per row), and the *canonical*
    // cross-PR trajectory at the repo root is written only by the
    // `fused_vs_baseline` bench — an ad-hoc grid must not overwrite it.
    if grid.backend == BackendChoice::Native {
        let json_path = util::results_dir().join("BENCH_native.json");
        bench::write_native_json(&rows, grid.planner, grid.simd, &json_path)?;
        println!("wrote native fused-vs-baseline summary to {}",
                 json_path.display());
    }

    println!("\n{}", render::table1(&rows));
    println!("{}", render::table2(&rows));
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let csv = match args.str_opt("csv") {
        Some(p) => std::path::PathBuf::from(p),
        None => util::results_dir().join("bench.csv"),
    };
    let which = args.str_or("which", "1");
    if which == "3" {
        // Table 3 measures live (stage pipeline), not from the CSV
        return cmd_profile(args);
    }
    let rows = metrics::read_csv(&csv)
        .with_context(|| format!("reading {csv:?} — run `fsa bench-grid` first"))?;
    if rows.is_empty() {
        bail!("{csv:?} contains no parseable rows — it may predate the \
               current schema (the k1,k2 columns became a single fanout \
               column, and imbalance + planner + simd columns were \
               appended); re-run `fsa bench-grid`");
    }
    let text = match which.as_str() {
        "1" => render::table1(&rows),
        "2" => render::table2(&rows),
        "fig1" => render::fig1(&rows),
        "fig2" => render::fig2(&rows),
        "fig3" => render::fig3(&rows),
        "fig4" => render::fig4(&rows),
        "fig5" => render::fig5(&rows),
        other => bail!("unknown exhibit {other:?}"),
    };
    println!("{text}");
    Ok(())
}

fn cmd_profile(args: &Args) -> Result<()> {
    let rt = Runtime::from_env()?;
    let mut cache = DatasetCache::new();
    let steps = args.usize_or("steps", 10)?;
    let warmup = args.usize_or("warmup", 2)?;
    let seed = args.u64_or("seed", 42)?;
    let report = profile::profile_baseline(&rt, &mut cache, warmup, steps,
                                           seed)?;
    println!("{}", render::table3(&report));
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    let name = args.str_or("dataset", "products_sim");
    let spec = builtin_spec(&name)?;
    let fanouts = args.fanout("fanout", &Fanouts::of(&[15, 10]))?;
    let batch = args.usize_or("batch", 1024)?;
    let dims = StepDims {
        batch,
        fanouts: fanouts.clone(),
        d: spec.d,
        hidden: 64,
        classes: spec.c,
        tile: args.usize_or("tile", 8)?, // CPU default (EXPERIMENTS §Perf)
    };
    let base = memory::baseline_transient(&dims);
    let fused = memory::fused_transient(&dims, true);
    println!("analytic transient model — {name} f{fanouts} ({}-hop) \
              b{batch}:", fanouts.depth());
    println!("  baseline: upload {} + intermediates {} + outputs {} = {}",
             util::fmt_bytes(base.upload), util::fmt_bytes(base.intermediates),
             util::fmt_bytes(base.outputs), util::fmt_bytes(base.peak_hbm()));
    println!("  fused:    upload {} + intermediates {} + outputs {} = {} \
              (+ VMEM tile {})",
             util::fmt_bytes(fused.upload),
             util::fmt_bytes(fused.intermediates),
             util::fmt_bytes(fused.outputs), util::fmt_bytes(fused.peak_hbm()),
             util::fmt_bytes(fused.vmem_tile));
    println!("  reduction: {:.2}x",
             base.peak_hbm() as f64 / fused.peak_hbm().max(1) as f64);
    Ok(())
}

/// `fsa throughput` — bench the host sampling/batch pipeline (steps/sec +
/// utilization) with the --threads / --prefetch knobs. Needs no artifacts:
/// dispatch is emulated (see bench::throughput docs).
fn cmd_throughput(args: &Args) -> Result<()> {
    let name = args.str_or("dataset", "arxiv_sim");
    let spec = builtin_spec(&name)?;
    let t = metrics::Timer::start();
    let ds = Arc::new(Dataset::generate(spec)?);
    println!("dataset {name}: {} nodes, {} edges ({:.0} ms to generate)",
             ds.spec.n, ds.graph.num_edges(), t.ms());

    let fanouts = args.fanout("fanout", &Fanouts::of(&[15, 10]))?;
    let native = match args.str_or("backend", "emulated").as_str() {
        "native" => true,
        "emulated" => false,
        other => bail!("throughput --backend must be emulated|native, \
                        got {other:?}"),
    };
    let variant = match args.str_or("variant", "dgl").as_str() {
        "fsa" => Variant::Fsa,
        "dgl" => Variant::Dgl,
        v => bail!("--variant must be fsa|dgl, got {v:?}"),
    };
    // native dispatch measures the same model as `fsa train --backend
    // native`: hyper-parameters come from the runtime manifest (the
    // builtin one when no artifacts exist or the manifest is unreadable)
    let (hidden, adamw) = match Runtime::from_env() {
        Ok(rt) => (rt.manifest.hidden, rt.manifest.adamw),
        Err(_) => {
            let b = Manifest::builtin();
            (b.hidden, b.adamw)
        }
    };
    let base_cfg = throughput::ThroughputConfig {
        fanouts,
        batch: args.usize_or("batch", 1024)?,
        steps: args.usize_or("steps", 30)?,
        warmup: args.usize_or("warmup", 3)?,
        threads: args.usize_or("threads", 1)?,
        prefetch: args.bool_or("prefetch", false)?,
        dispatch_ms: args
            .str_opt("dispatch-ms")
            .map(|v| v.parse::<f64>().context("bad --dispatch-ms"))
            .transpose()?
            .unwrap_or(2.0),
        seed: args.u64_or("seed", 42)?,
        native,
        variant,
        hidden,
        adamw,
        planner: planner_choice(args)?,
        simd: simd_choice(args)?,
        layout: layout_choice(args)?,
        hub_cache: hub_cache_arg(args)?,
        ..throughput::ThroughputConfig::new(&name)
    };

    let mut rows = Vec::new();
    if args.has("sweep") {
        for threads in [1usize, 2, 4, 8] {
            for prefetch in [false, true] {
                let cfg = throughput::ThroughputConfig {
                    threads,
                    prefetch,
                    ..base_cfg.clone()
                };
                let row = throughput::run_throughput(ds.clone(), &cfg)?;
                eprintln!("  t{threads} prefetch={}: {:.1} steps/s",
                          if prefetch { "on " } else { "off" },
                          row.steps_per_s);
                rows.push(row);
            }
        }
    } else {
        rows.push(throughput::run_throughput(ds.clone(), &base_cfg)?);
    }
    println!("\n{}", throughput::render_table(&rows));

    let out_path = match args.str_opt("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => util::results_dir().join("throughput.csv"),
    };
    metrics::write_throughput_csv(&out_path, &rows)?;
    println!("wrote {} rows to {}", rows.len(), out_path.display());
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let rt = Runtime::from_env()?;
    if args.has("list") {
        for (name, a) in &rt.manifest.artifacts {
            println!("{:<44} {:<6} {:<10} in:{:<3} out:{}", name, a.kind,
                     a.dataset, a.inputs.len(), a.outputs.len());
        }
        return Ok(());
    }
    let name = args
        .str_opt("artifact")
        .context("--artifact NAME or --list required")?;
    let a = rt.manifest.artifact(name)?;
    println!("{} ({}, {})", a.name, a.kind, a.file);
    println!("  dataset {}  fanout {}x{}  batch {}  amp {}  save_indices {} \
              tile {}",
             a.dataset, a.k1, a.k2, a.batch, a.amp, a.save_indices, a.tile);
    println!("  inputs:");
    for t in &a.inputs {
        println!("    {:<14} {:?} {:?} ({})", t.name, t.shape, t.dtype,
                 util::fmt_bytes(t.bytes()));
    }
    println!("  outputs:");
    for t in &a.outputs {
        println!("    {:<14} {:?} {:?} ({})", t.name, t.shape, t.dtype,
                 util::fmt_bytes(t.bytes()));
    }
    Ok(())
}
