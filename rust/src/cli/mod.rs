//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `fsa <subcommand> [--key value]... [--switch]... [positional]...`
//! A `--key` is a switch when it is followed by another `--key` or nothing;
//! otherwise it consumes the next token as its value.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::fanout::Fanouts;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (after argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut it = tokens.into_iter().peekable();
        let mut args = Args {
            subcommand: it.next().unwrap_or_default(),
            ..Default::default()
        };
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare '--' not supported");
                }
                // --key=value form
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                    continue;
                }
                match it.peek() {
                    Some(next) if !next.starts_with("--") => {
                        let v = it.next().unwrap();
                        args.options.insert(key.to_string(), v);
                    }
                    _ => args.switches.push(key.to_string()),
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// Boolean option: `--key on|off|true|false|1|0|yes|no`, or a bare
    /// `--key` switch meaning "on".
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        if let Some(v) = self.str_opt(key) {
            return match v {
                "on" | "true" | "1" | "yes" => Ok(true),
                "off" | "false" | "0" | "no" => Ok(false),
                other => Err(anyhow!("--{key} expects on|off, got {other:?}")),
            };
        }
        Ok(self.has(key) || default)
    }

    /// Comma-separated list option.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.str_opt(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Fanout option: any depth, e.g. "10" (1-hop), "15x10", "15x10x5".
    pub fn fanout(&self, key: &str, default: &Fanouts) -> Result<Fanouts> {
        match self.str_opt(key) {
            None => Ok(default.clone()),
            Some(v) => parse_fanout(v)
                .map_err(|e| anyhow!("--{key}: {e}")),
        }
    }
}

/// Parse an arbitrary-depth fanout string — "k1xk2x…" / "k1_k2_…" /
/// "k1,k2,…" / "k1" — into an ordered [`Fanouts`]. The legacy "15x10"
/// and "10" forms parse identically to the pre-depth-generic CLI.
pub fn parse_fanout(s: &str) -> Result<Fanouts> {
    Fanouts::parse(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_options_switches() {
        let a = parse(&["train", "--dataset", "tiny", "--quick",
                        "--steps", "30", "pos1"]);
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.str_opt("dataset"), Some("tiny"));
        assert!(a.has("quick"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 30);
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn key_equals_value() {
        let a = parse(&["x", "--k=v", "--n=3"]);
        assert_eq!(a.str_opt("k"), Some("v"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 3);
    }

    #[test]
    fn trailing_switch() {
        let a = parse(&["x", "--flag"]);
        assert!(a.has("flag"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["x"]);
        assert_eq!(a.str_or("dataset", "tiny"), "tiny");
        assert_eq!(a.usize_or("steps", 30).unwrap(), 30);
        assert_eq!(a.u64_or("seed", 42).unwrap(), 42);
    }

    #[test]
    fn bad_int_reports_key() {
        let a = parse(&["x", "--steps", "abc"]);
        let err = a.usize_or("steps", 0).unwrap_err().to_string();
        assert!(err.contains("steps"));
    }

    #[test]
    fn bool_option_forms() {
        let a = parse(&["x", "--prefetch", "on", "--amp", "off"]);
        assert!(a.bool_or("prefetch", false).unwrap());
        assert!(!a.bool_or("amp", true).unwrap());
        assert!(a.bool_or("missing", true).unwrap());
        assert!(!a.bool_or("missing", false).unwrap());
        let b = parse(&["x", "--prefetch"]);
        assert!(b.bool_or("prefetch", false).unwrap());
        let c = parse(&["x", "--prefetch", "maybe"]);
        assert!(c.bool_or("prefetch", false).is_err());
    }

    #[test]
    fn fanout_forms() {
        // legacy 1/2-hop forms parse to the same configurations as before
        assert_eq!(parse_fanout("15x10").unwrap(), Fanouts::of(&[15, 10]));
        assert_eq!(parse_fanout("15_10").unwrap(), Fanouts::of(&[15, 10]));
        assert_eq!(parse_fanout("10").unwrap(), Fanouts::of(&[10]));
        // arbitrary depth, both separators
        assert_eq!(parse_fanout("15x10x5").unwrap(),
                   Fanouts::of(&[15, 10, 5]));
        assert_eq!(parse_fanout("15,10,5").unwrap(),
                   Fanouts::of(&[15, 10, 5]));
        // empty / zero segments are clear errors
        assert!(parse_fanout("x").is_err());
        assert!(parse_fanout("15x").is_err());
        assert!(parse_fanout("15x0x5").is_err());
        let a = parse(&["x", "--fanout", "10x5x5"]);
        assert_eq!(a.fanout("fanout", &Fanouts::of(&[15, 10])).unwrap(),
                   Fanouts::of(&[10, 5, 5]));
        let b = parse(&["x"]);
        assert_eq!(b.fanout("fanout", &Fanouts::of(&[15, 10])).unwrap(),
                   Fanouts::of(&[15, 10]));
        let c = parse(&["x", "--fanout", "bogus"]);
        let err = c.fanout("fanout", &Fanouts::of(&[15, 10]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("fanout"), "{err}");
    }

    #[test]
    fn list_option() {
        let a = parse(&["x", "--datasets", "a, b,c"]);
        assert_eq!(a.list_or("datasets", &["z"]), vec!["a", "b", "c"]);
        assert_eq!(a.list_or("missing", &["z"]), vec!["z"]);
    }
}
