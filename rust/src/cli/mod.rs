//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `fsa <subcommand> [--key value]... [--switch]... [positional]...`
//! A `--key` is a switch when it is followed by another `--key` or nothing;
//! otherwise it consumes the next token as its value.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::fanout::Fanouts;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (after argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut it = tokens.into_iter().peekable();
        let mut args = Args {
            subcommand: it.next().unwrap_or_default(),
            ..Default::default()
        };
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare '--' not supported");
                }
                // --key=value form
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                    continue;
                }
                match it.peek() {
                    Some(next) if !next.starts_with("--") => {
                        let v = it.next().unwrap();
                        args.options.insert(key.to_string(), v);
                    }
                    _ => args.switches.push(key.to_string()),
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// Boolean option: `--key on|off|true|false|1|0|yes|no`, or a bare
    /// `--key` switch meaning "on".
    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        if let Some(v) = self.str_opt(key) {
            return match v {
                "on" | "true" | "1" | "yes" => Ok(true),
                "off" | "false" | "0" | "no" => Ok(false),
                other => Err(anyhow!("--{key} expects on|off, got {other:?}")),
            };
        }
        Ok(self.has(key) || default)
    }

    /// Comma-separated list option.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.str_opt(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Fanout option: any depth, e.g. "10" (1-hop), "15x10", "15x10x5".
    pub fn fanout(&self, key: &str, default: &Fanouts) -> Result<Fanouts> {
        match self.str_opt(key) {
            None => Ok(default.clone()),
            Some(v) => parse_fanout(v)
                .map_err(|e| anyhow!("--{key}: {e}")),
        }
    }

    /// Reject options/switches the subcommand does not understand, with
    /// an edit-distance "did you mean" hint — so a typo like
    /// `--batch-windw-ms` fails loudly instead of silently applying the
    /// default.
    pub fn ensure_known(&self, options: &[&str],
                        switches: &[&str]) -> Result<()> {
        for key in self.options.keys() {
            if !options.contains(&key.as_str()) {
                if switches.contains(&key.as_str()) {
                    // a known switch given a value parses as an option;
                    // accept it (bool_or handles the on/off value)
                    continue;
                }
                bail!("unknown option --{key} for `fsa {}`{}",
                      self.subcommand,
                      did_you_mean(key, options, switches));
            }
        }
        for key in &self.switches {
            if !switches.contains(&key.as_str()) {
                if options.contains(&key.as_str()) {
                    bail!("--{key} expects a value");
                }
                bail!("unknown option --{key} for `fsa {}`{}",
                      self.subcommand,
                      did_you_mean(key, options, switches));
            }
        }
        Ok(())
    }
}

/// Every subcommand with a one-line summary — single source of truth for
/// `fsa help` and the unknown-subcommand error.
pub const SUBCOMMANDS: &[(&str, &str)] = &[
    ("gen", "generate a synthetic dataset into the artifact cache"),
    ("train", "train a model (optionally saving a params checkpoint)"),
    ("serve", "micro-batched online inference over a trained model"),
    ("bench-grid", "sweep the variant x config bench grid to bench.csv"),
    ("throughput", "pipeline throughput sweep to throughput.csv"),
    ("table", "render a results CSV as an aligned table"),
    ("profile", "per-phase step timing breakdown"),
    ("memory", "peak transient memory accounting"),
    ("inspect", "dump dataset / artifact metadata"),
    ("help", "this overview"),
];

/// Indented `name  summary` listing of [`SUBCOMMANDS`].
pub fn subcommand_summary() -> String {
    let mut out = String::new();
    for (name, what) in SUBCOMMANDS {
        out.push_str(&format!("  {name:<11} {what}\n"));
    }
    out
}

/// `"; did you mean --<candidate>?"` when some known key is close
/// enough to the typo, else empty.
fn did_you_mean(key: &str, options: &[&str], switches: &[&str]) -> String {
    let best = options
        .iter()
        .chain(switches.iter())
        .map(|c| (levenshtein(key, c), *c))
        .min();
    match best {
        Some((d, c)) if d <= 2 || d * 3 <= key.len() => {
            format!("; did you mean --{c}?")
        }
        _ => String::new(),
    }
}

/// Classic two-row edit distance, over bytes (keys are ASCII).
pub fn levenshtein(a: &str, b: &str) -> usize {
    let (a, b) = (a.as_bytes(), b.as_bytes());
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Parse an arbitrary-depth fanout string — "k1xk2x…" / "k1_k2_…" /
/// "k1,k2,…" / "k1" — into an ordered [`Fanouts`]. The legacy "15x10"
/// and "10" forms parse identically to the pre-depth-generic CLI.
pub fn parse_fanout(s: &str) -> Result<Fanouts> {
    Fanouts::parse(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_options_switches() {
        let a = parse(&["train", "--dataset", "tiny", "--quick",
                        "--steps", "30", "pos1"]);
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.str_opt("dataset"), Some("tiny"));
        assert!(a.has("quick"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 30);
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn key_equals_value() {
        let a = parse(&["x", "--k=v", "--n=3"]);
        assert_eq!(a.str_opt("k"), Some("v"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 3);
    }

    #[test]
    fn trailing_switch() {
        let a = parse(&["x", "--flag"]);
        assert!(a.has("flag"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["x"]);
        assert_eq!(a.str_or("dataset", "tiny"), "tiny");
        assert_eq!(a.usize_or("steps", 30).unwrap(), 30);
        assert_eq!(a.u64_or("seed", 42).unwrap(), 42);
    }

    #[test]
    fn bad_int_reports_key() {
        let a = parse(&["x", "--steps", "abc"]);
        let err = a.usize_or("steps", 0).unwrap_err().to_string();
        assert!(err.contains("steps"));
    }

    #[test]
    fn bool_option_forms() {
        let a = parse(&["x", "--prefetch", "on", "--amp", "off"]);
        assert!(a.bool_or("prefetch", false).unwrap());
        assert!(!a.bool_or("amp", true).unwrap());
        assert!(a.bool_or("missing", true).unwrap());
        assert!(!a.bool_or("missing", false).unwrap());
        let b = parse(&["x", "--prefetch"]);
        assert!(b.bool_or("prefetch", false).unwrap());
        let c = parse(&["x", "--prefetch", "maybe"]);
        assert!(c.bool_or("prefetch", false).is_err());
    }

    #[test]
    fn fanout_forms() {
        // legacy 1/2-hop forms parse to the same configurations as before
        assert_eq!(parse_fanout("15x10").unwrap(), Fanouts::of(&[15, 10]));
        assert_eq!(parse_fanout("15_10").unwrap(), Fanouts::of(&[15, 10]));
        assert_eq!(parse_fanout("10").unwrap(), Fanouts::of(&[10]));
        // arbitrary depth, both separators
        assert_eq!(parse_fanout("15x10x5").unwrap(),
                   Fanouts::of(&[15, 10, 5]));
        assert_eq!(parse_fanout("15,10,5").unwrap(),
                   Fanouts::of(&[15, 10, 5]));
        // empty / zero segments are clear errors
        assert!(parse_fanout("x").is_err());
        assert!(parse_fanout("15x").is_err());
        assert!(parse_fanout("15x0x5").is_err());
        let a = parse(&["x", "--fanout", "10x5x5"]);
        assert_eq!(a.fanout("fanout", &Fanouts::of(&[15, 10])).unwrap(),
                   Fanouts::of(&[10, 5, 5]));
        let b = parse(&["x"]);
        assert_eq!(b.fanout("fanout", &Fanouts::of(&[15, 10])).unwrap(),
                   Fanouts::of(&[15, 10]));
        let c = parse(&["x", "--fanout", "bogus"]);
        let err = c.fanout("fanout", &Fanouts::of(&[15, 10]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("fanout"), "{err}");
    }

    #[test]
    fn unknown_options_are_rejected_with_hint() {
        const OPTS: &[&str] = &["batch-window-ms", "max-batch",
                                "queue-depth", "dataset"];
        const SWITCHES: &[&str] = &["bench"];
        // clean invocations pass
        let ok = parse(&["serve", "--batch-window-ms", "2",
                         "--dataset", "tiny", "--bench"]);
        ok.ensure_known(OPTS, SWITCHES).unwrap();
        // the motivating typo: suggests the real flag
        let typo = parse(&["serve", "--batch-windw-ms", "2"]);
        let err = typo.ensure_known(OPTS, SWITCHES).unwrap_err()
            .to_string();
        assert!(err.contains("unknown option --batch-windw-ms"), "{err}");
        assert!(err.contains("`fsa serve`"), "{err}");
        assert!(err.contains("did you mean --batch-window-ms?"), "{err}");
        // a known option used as a bare switch asks for its value
        let bare = parse(&["serve", "--queue-depth"]);
        let err = bare.ensure_known(OPTS, SWITCHES).unwrap_err()
            .to_string();
        assert!(err.contains("--queue-depth expects a value"), "{err}");
        // unknown switch, nothing nearby: no bogus suggestion
        let junk = parse(&["serve", "--zzzzzz"]);
        let err = junk.ensure_known(OPTS, SWITCHES).unwrap_err()
            .to_string();
        assert!(err.contains("unknown option --zzzzzz"), "{err}");
        assert!(!err.contains("did you mean"), "{err}");
        // a known switch given an on/off value still passes
        let sw = parse(&["serve", "--bench", "on"]);
        sw.ensure_known(OPTS, SWITCHES).unwrap();
    }

    #[test]
    fn levenshtein_basics() {
        assert_eq!(levenshtein("", ""), 0);
        assert_eq!(levenshtein("abc", "abc"), 0);
        assert_eq!(levenshtein("abc", ""), 3);
        assert_eq!(levenshtein("kitten", "sitting"), 3);
        assert_eq!(levenshtein("batch-windw-ms", "batch-window-ms"), 1);
    }

    #[test]
    fn subcommand_listing_covers_serve() {
        assert!(SUBCOMMANDS.iter().any(|(n, _)| *n == "serve"));
        assert!(SUBCOMMANDS.iter().any(|(n, _)| *n == "help"));
        let listing = subcommand_summary();
        assert!(listing.contains("serve"));
        assert!(listing.lines().count() == SUBCOMMANDS.len());
    }

    #[test]
    fn list_option() {
        let a = parse(&["x", "--datasets", "a, b,c"]);
        assert_eq!(a.list_or("datasets", &["z"]), vec!["a", "b", "c"]);
        assert_eq!(a.list_or("missing", &["z"]), vec!["z"]);
    }
}
