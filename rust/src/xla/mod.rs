//! In-crate stand-in for the `xla` (PJRT / xla_extension) bindings.
//!
//! The original build linked the vendored `xla` crate (xla_extension 0.5.1)
//! to compile and dispatch the AOT-lowered HLO artifacts. That native
//! dependency is not available in this offline build, so this module
//! provides the exact API surface [`crate::runtime`] and
//! [`crate::coordinator`] consume, with honest semantics:
//!
//! * literals and device "buffers" are real host-side containers (typed
//!   byte storage with shape/dtype bookkeeping), so upload paths, size
//!   accounting, and dtype conversion behave correctly;
//! * `PjRtClient::compile` returns an error — there is no HLO compiler
//!   here, and faking execution would corrupt every measurement. The
//!   host-side pipeline (dataset generation, sampling, sharding, prefetch,
//!   the `throughput` bench mode, the analytic memory model) is fully
//!   functional without it.
//!
//! Swapping the real bindings back in is mechanical: delete this module
//! and replace the `use crate::xla;` imports in `runtime`, `coordinator`,
//! and `coordinator::profile` with the external crate.

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring the bindings' error enum (string-backed here).
#[derive(Debug)]
pub struct XlaError {
    msg: String,
}

impl XlaError {
    fn new(msg: impl Into<String>) -> Self {
        XlaError { msg: msg.into() }
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla (stub): {}", self.msg)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

/// XLA element types used by the AOT contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
    U64,
    Bf16,
    F16,
}

impl PrimitiveType {
    pub fn byte_size(&self) -> usize {
        match self {
            PrimitiveType::F32 | PrimitiveType::S32 => 4,
            PrimitiveType::U64 => 8,
            PrimitiveType::Bf16 | PrimitiveType::F16 => 2,
        }
    }
}

/// Host native types that can back a literal.
pub trait NativeType: Copy {
    const TY: PrimitiveType;
    fn write_bytes(self, out: &mut Vec<u8>);
    fn read_bytes(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: PrimitiveType = PrimitiveType::F32;
    fn write_bytes(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_bytes(bytes: &[u8]) -> Self {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl NativeType for i32 {
    const TY: PrimitiveType = PrimitiveType::S32;
    fn write_bytes(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_bytes(bytes: &[u8]) -> Self {
        i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl NativeType for u64 {
    const TY: PrimitiveType = PrimitiveType::U64;
    fn write_bytes(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_bytes(bytes: &[u8]) -> Self {
        let mut b = [0u8; 8];
        b.copy_from_slice(&bytes[..8]);
        u64::from_le_bytes(b)
    }
}

/// A host literal: typed byte storage + dims, or a tuple of literals.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: PrimitiveType,
    dims: Vec<i64>,
    data: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let mut bytes = Vec::with_capacity(data.len() * T::TY.byte_size());
        for &v in data {
            v.write_bytes(&mut bytes);
        }
        Literal {
            ty: T::TY,
            dims: vec![data.len() as i64],
            data: bytes,
            tuple: None,
        }
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product::<i64>().max(0) as usize
    }

    /// Total payload bytes (sum over leaves for tuples).
    pub fn size_bytes(&self) -> usize {
        match &self.tuple {
            Some(parts) => parts.iter().map(Literal::size_bytes).sum(),
            None => self.data.len(),
        }
    }

    /// Reshape to new dims with the same element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if self.tuple.is_some() {
            return Err(XlaError::new("cannot reshape a tuple literal"));
        }
        let new_count = dims.iter().product::<i64>().max(0) as usize;
        if new_count != self.element_count() {
            return Err(XlaError::new(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal { ty: self.ty, dims: dims.to_vec(), data: self.data.clone(), tuple: None })
    }

    /// Element-type conversion. Supports the identity and the f32 -> bf16
    /// path the runtime uses (round-to-nearest-even, like the kernels).
    pub fn convert(&self, ty: PrimitiveType) -> Result<Literal> {
        if self.tuple.is_some() {
            return Err(XlaError::new("cannot convert a tuple literal"));
        }
        if ty == self.ty {
            return Ok(self.clone());
        }
        match (self.ty, ty) {
            (PrimitiveType::F32, PrimitiveType::Bf16) => {
                let mut out = Vec::with_capacity(self.element_count() * 2);
                for chunk in self.data.chunks_exact(4) {
                    let x = f32::read_bytes(chunk);
                    let bits = x.to_bits();
                    let bf16 = if x.is_nan() {
                        0x7FC0u16
                    } else {
                        let round = 0x7FFF + ((bits >> 16) & 1);
                        ((bits.wrapping_add(round)) >> 16) as u16
                    };
                    out.extend_from_slice(&bf16.to_le_bytes());
                }
                Ok(Literal { ty, dims: self.dims.clone(), data: out, tuple: None })
            }
            (from, to) => Err(XlaError::new(format!(
                "conversion {from:?} -> {to:?} not supported by the stub"
            ))),
        }
    }

    /// First element, checked against the requested native type.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        if self.ty != T::TY {
            return Err(XlaError::new(format!(
                "type mismatch: literal is {:?}", self.ty
            )));
        }
        let sz = T::TY.byte_size();
        if self.data.len() < sz {
            return Err(XlaError::new("empty literal"));
        }
        Ok(T::read_bytes(&self.data[..sz]))
    }

    /// Full payload as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(XlaError::new(format!(
                "type mismatch: literal is {:?}", self.ty
            )));
        }
        let sz = T::TY.byte_size();
        Ok(self.data.chunks_exact(sz).map(T::read_bytes).collect())
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.tuple {
            Some(parts) => Ok(parts.clone()),
            None => Err(XlaError::new("literal is not a tuple")),
        }
    }
}

/// A "device" buffer — host-resident here; keeps upload paths type-correct.
#[derive(Clone, Debug)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// Synchronized device-to-host copy.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// Parsed HLO module (text retained for diagnostics only).
#[derive(Debug)]
pub struct HloModuleProto {
    /// HLO text size, reported in the compile error for context.
    bytes: usize,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| XlaError::new(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { bytes: text.len() })
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    bytes: usize,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { bytes: proto.bytes }
    }
}

/// A compiled executable. Never constructed by the stub (compile errors),
/// but the type must exist for the runtime's executable cache.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<L: Borrow<PjRtBuffer>>(
        &self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::new(
            "stub backend cannot execute; rebuild with the real PJRT bindings"))
    }
}

/// PJRT client. Buffer management works; compilation is unavailable.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::new(format!(
            "cannot compile HLO ({} bytes): the PJRT bindings (xla_extension) \
             are not vendored in this build. Host-side subcommands \
             (gen/memory/throughput) and all pure-rust tests remain available",
            comp.bytes
        )))
    }

    /// Upload a typed host slice as a buffer; dims must match the length.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self, data: &[T], dims: &[usize], _device: Option<usize>)
        -> Result<PjRtBuffer> {
        let count: usize = dims.iter().product();
        if count != data.len() {
            return Err(XlaError::new(format!(
                "buffer_from_host_buffer: dims {:?} ({} elements) vs data len {}",
                dims, count, data.len()
            )));
        }
        let lit = Literal::vec1(data);
        let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        let lit = if dims.len() == 1 {
            lit
        } else {
            Literal { ty: lit.ty, dims: dims_i64, data: lit.data, tuple: None }
        };
        Ok(PjRtBuffer { literal: lit })
    }

    /// Upload an existing literal as a buffer.
    pub fn buffer_from_host_literal(
        &self, _device: Option<usize>, lit: &Literal) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { literal: lit.clone() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_sizes() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(lit.size_bytes(), 16);
        assert_eq!(lit.element_count(), 4);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 1.0);
        assert!(lit.get_first_element::<i32>().is_err());
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.size_bytes(), 16);
        assert!(lit.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn bf16_conversion_matches_runtime_helper() {
        let xs = [1.0f32, -3.5, 0.1, f32::NAN];
        let lit = Literal::vec1(&xs).convert(PrimitiveType::Bf16).unwrap();
        assert_eq!(lit.data, crate::runtime::f32_to_bf16_bytes(&xs));
    }

    #[test]
    fn client_buffers_check_dims() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.buffer_from_host_buffer(&[1i32, 2], &[2], None).is_ok());
        assert!(c.buffer_from_host_buffer(&[1i32, 2], &[3], None).is_err());
        // scalar: empty dims = one element (product of [] is 1)
        assert!(c.buffer_from_host_buffer(&[7.0f32], &[], None).is_ok());
    }

    #[test]
    fn compile_is_an_explicit_error() {
        let c = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto { bytes: 10 });
        let err = c.compile(&comp).unwrap_err().to_string();
        assert!(err.contains("stub") || err.contains("not vendored"), "{err}");
    }
}
