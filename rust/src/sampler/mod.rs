//! Host-side neighbor sampling — the DGL `NeighborSampler` analogue.
//!
//! The baseline pipeline (sample → materialize → aggregate) runs this
//! sampler on the host, uploads the index tensors, and lets the baseline
//! executable gather/aggregate — exactly the structure the paper attacks.
//!
//! The sampling rule is the counter-hash rule of DESIGN.md §5, implemented
//! bit-for-bit like the Pallas kernel (`python/compile/kernels/sampling.py`)
//! so both variants draw identical neighborhoods; parity is pinned by golden
//! vectors generated from the python oracle and by the integration tests.
//!
//! Depth is a parameter, not a code path: [`build_block`] builds the
//! no-dedup nested frontier tensors of an L-hop [`Block`] for any
//! [`Fanouts`], one expansion loop instead of per-depth builders.
//!
//! [`reservoir`] provides the paper's Alg. 1 uniform-without-replacement
//! sampler (used for validation; see the substitution note in DESIGN.md §3).
//! [`parallel`] shards the frontier across a scoped-thread worker pool —
//! bitwise identical output at any thread count.

pub mod parallel;
pub mod reservoir;

pub use parallel::ParallelSampler;

use crate::fanout::Fanouts;
use crate::graph::Csr;
use crate::rng::rand_counter;

/// Sample up to `k` neighbors of `node` into `out[..k]` (-1 padded).
///
/// Rule: invalid node / deg==0 → all -1; deg<=k → take-all in CSR order;
/// deg>k → slot i takes `col[start + rand(base,node,hop,i) % deg]`.
pub fn sample_neighbors(csr: &Csr, node: i32, k: usize, base: u64, hop: u64,
                        out: &mut [i32]) {
    debug_assert!(out.len() >= k);
    if node < 0 {
        out[..k].fill(-1);
        return;
    }
    let start = csr.rowptr[node as usize] as usize;
    let deg = csr.degree(node) as usize;
    if deg == 0 {
        out[..k].fill(-1);
        return;
    }
    if deg <= k {
        for i in 0..k {
            out[i] = if i < deg { csr.col[start + i] } else { -1 };
        }
        return;
    }
    for (i, o) in out.iter_mut().take(k).enumerate() {
        let r = rand_counter(base, node as u64, hop, i as u64);
        *o = csr.col[start + (r % deg as u64) as usize];
    }
}

/// Sample `k` neighbors for every node of a frontier; returns row-major
/// `[frontier.len(), k]`, -1 padded.
pub fn sample_frontier(csr: &Csr, frontier: &[i32], k: usize, base: u64,
                       hop: u64) -> Vec<i32> {
    let mut out = vec![-1i32; frontier.len() * k];
    for (i, &u) in frontier.iter().enumerate() {
        sample_neighbors(csr, u, k, base, hop, &mut out[i * k..(i + 1) * k]);
    }
    out
}

/// Self-inclusive frontier expansion: `[nodes.len(), 1+k]` with column 0
/// the node itself and columns 1.. its `k` hop-`hop` samples (the nested
/// layout every baseline level uses; invalid nodes expand to -1 rows).
pub fn expand_frontier(csr: &Csr, nodes: &[i32], k: usize, base: u64,
                       hop: u64) -> Vec<i32> {
    let w = 1 + k;
    let mut out = vec![-1i32; nodes.len() * w];
    for (i, &u) in nodes.iter().enumerate() {
        out[i * w] = u;
        sample_neighbors(csr, u, k, base, hop, &mut out[i * w + 1..(i + 1) * w]);
    }
    out
}

/// The index tensors one baseline L-hop step uploads (DGL's "blocks"),
/// depth-generic and no-dedup (static shapes; DESIGN.md §10 discusses the
/// deviation from DGL's MFGs).
///
/// `frontiers[l]` is the self-inclusive frontier at depth `l`: level 0 is
/// the `[B, 1]` seeds; level `l > 0` nests each level-`l-1` node with its
/// `k_l` hop-`l-1` samples, width `Π_{j≤l}(1+k_j)`. `leaf` holds the last
/// hop's samples only (`[B, Π_{j<L}(1+k_j) · k_L]`) — the tensor whose
/// dense feature gather is the materialization cost the fused op removes.
///
/// Depth-2 instance: `frontiers[1]` is the legacy `f1 = [B, 1+k1]` and
/// `leaf` the legacy `s2 = [B, (1+k1), k2]`, with identical draws.
pub struct Block {
    pub batch: usize,
    pub fanouts: Fanouts,
    pub frontiers: Vec<Vec<i32>>,
    pub leaf: Vec<i32>,
}

impl Block {
    /// Total uploaded index ints (frontier levels past the seeds + leaf).
    pub fn index_len(&self) -> usize {
        self.frontiers[1..].iter().map(|f| f.len()).sum::<usize>()
            + self.leaf.len()
    }
}

/// Build the L-hop nested frontier + leaf tensors for a batch of seeds:
/// one expansion loop over the fanout list (hop `l` draws with counter
/// index `l`, exactly like the fused kernel).
pub fn build_block(csr: &Csr, seeds: &[i32], fanouts: &Fanouts,
                   base: u64) -> Block {
    let depth = fanouts.depth();
    let mut frontiers: Vec<Vec<i32>> = Vec::with_capacity(depth);
    frontiers.push(seeds.to_vec());
    for hop in 0..depth - 1 {
        let next = expand_frontier(csr, &frontiers[hop], fanouts.k(hop),
                                   base, hop as u64);
        frontiers.push(next);
    }
    let leaf = sample_frontier(csr, &frontiers[depth - 1],
                               fanouts.k(depth - 1), base,
                               (depth - 1) as u64);
    Block { batch: seeds.len(), fanouts: fanouts.clone(), frontiers, leaf }
}

/// Count of valid (non `-1`) entries — the paper's raw "sampled pairs" unit.
pub fn valid_pairs(indices: &[i32]) -> u64 {
    indices.iter().filter(|&&v| v >= 0).count() as u64
}

/// Distinct valid ids — DGL's de-duplicated "block edges" style unit
/// (reported alongside for the Threats-to-Validity comparison).
pub fn distinct_nodes(indices: &[i32]) -> u64 {
    let mut ids: Vec<i32> = indices.iter().copied().filter(|&v| v >= 0).collect();
    ids.sort_unstable();
    ids.dedup();
    ids.len() as u64
}

/// Raw sampled pairs of one *fused* L-hop step (every hop's valid draws,
/// leaves drawn only below valid parents), computable without running the
/// kernel because the host sampler is bitwise-identical to it.
pub fn fused_sampled_pairs(csr: &Csr, seeds: &[i32], fanouts: &Fanouts,
                           base: u64) -> u64 {
    let mut frontier = seeds.to_vec();
    let mut total = 0u64;
    for hop in 0..fanouts.depth() {
        let s = sample_frontier(csr, &frontier, fanouts.k(hop), base,
                                hop as u64);
        total += valid_pairs(&s);
        frontier = s;
    }
    total
}

/// Raw sampled pairs of one baseline L-hop step: every *sampled* slot of
/// every frontier level (the self slots are carried nodes, not draws)
/// plus the leaf draws. The baseline frontier includes the parents
/// themselves, so it genuinely samples more pairs than the fused op.
pub fn block_sampled_pairs(block: &Block) -> u64 {
    let mut total = 0u64;
    for (l, level) in block.frontiers.iter().enumerate().skip(1) {
        let gw = 1 + block.fanouts.k(l - 1);
        total += level
            .chunks_exact(gw)
            .map(|group| valid_pairs(&group[1..]))
            .sum::<u64>();
    }
    total + valid_pairs(&block.leaf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{builtin_spec, Dataset};
    use crate::rng::SplitMix64;

    fn test_graph() -> Csr {
        Dataset::generate(builtin_spec("tiny").unwrap()).unwrap().graph
    }

    #[test]
    fn take_all_when_degree_small() {
        let csr = Csr::from_edges(4, &[(0, 1), (0, 2)], 16, true).unwrap();
        let mut out = [0i32; 5];
        sample_neighbors(&csr, 0, 5, 42, 0, &mut out);
        assert_eq!(&out[..2], &[1, 2]);
        assert_eq!(&out[2..], &[-1, -1, -1]);
    }

    #[test]
    fn isolated_and_invalid_nodes_pad() {
        let csr = Csr::from_edges(4, &[(0, 1)], 16, true).unwrap();
        let mut out = [7i32; 3];
        sample_neighbors(&csr, 2, 3, 42, 0, &mut out);
        assert_eq!(out, [-1, -1, -1]);
        sample_neighbors(&csr, -1, 3, 42, 0, &mut out);
        assert_eq!(out, [-1, -1, -1]);
    }

    #[test]
    fn samples_are_neighbors_and_deterministic() {
        let csr = test_graph();
        let mut a = vec![0i32; 4];
        let mut b = vec![0i32; 4];
        for u in 0..csr.n as i32 {
            sample_neighbors(&csr, u, 4, 7, 0, &mut a);
            for &v in &a {
                if v >= 0 {
                    assert!(csr.neighbors(u).contains(&v));
                }
            }
            sample_neighbors(&csr, u, 4, 7, 0, &mut b);
            assert_eq!(a, b, "non-deterministic for node {u}");
        }
    }

    #[test]
    fn base_seed_changes_samples() {
        let csr = test_graph();
        // find a node with degree > k so the random path is taken
        let u = (0..csr.n as i32).find(|&u| csr.degree(u) > 3).unwrap();
        let mut a = vec![0i32; 3];
        let mut b = vec![0i32; 3];
        sample_neighbors(&csr, u, 3, 1, 0, &mut a);
        sample_neighbors(&csr, u, 3, 2, 0, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn block_level1_embeds_seed_and_hop0_samples() {
        let csr = test_graph();
        let seeds = [3i32, 100, 200];
        let blk = build_block(&csr, &seeds, &Fanouts::of(&[4, 2]), 42);
        let f1w = 5;
        assert_eq!(blk.frontiers.len(), 2);
        assert_eq!(blk.frontiers[0], seeds);
        for (bi, &r) in seeds.iter().enumerate() {
            assert_eq!(blk.frontiers[1][bi * f1w], r);
            let mut want = vec![0i32; 4];
            sample_neighbors(&csr, r, 4, 42, 0, &mut want);
            assert_eq!(&blk.frontiers[1][bi * f1w + 1..(bi + 1) * f1w],
                       &want[..]);
        }
        assert_eq!(blk.leaf.len(), 3 * f1w * 2);
        assert_eq!(blk.index_len(), 3 * f1w + 3 * f1w * 2);
    }

    /// Depth-3 nesting: every level-2 group starts with its level-1 node
    /// (the self slot) followed by that node's hop-1 samples, and the leaf
    /// rows are the hop-2 samples of the level-2 nodes.
    #[test]
    fn block_depth3_nests_self_and_samples() {
        let csr = test_graph();
        let seeds: Vec<i32> = (0..8).collect();
        let fo = Fanouts::of(&[3, 2, 2]);
        let blk = build_block(&csr, &seeds, &fo, 7);
        let (w1, w2) = (4usize, 3usize); // 1+k1 group, 1+k2 group
        assert_eq!(blk.frontiers[1].len(), 8 * w1);
        assert_eq!(blk.frontiers[2].len(), 8 * w1 * w2);
        assert_eq!(blk.leaf.len(), 8 * w1 * w2 * 2);
        let mut buf = vec![0i32; 2];
        for p in 0..8 * w1 {
            let u = blk.frontiers[1][p];
            let group = &blk.frontiers[2][p * w2..(p + 1) * w2];
            assert_eq!(group[0], u, "self slot at {p}");
            sample_neighbors(&csr, u, 2, 7, 1, &mut buf);
            assert_eq!(&group[1..], &buf[..], "hop-1 samples at {p}");
        }
        for (q, &v) in blk.frontiers[2].iter().enumerate() {
            sample_neighbors(&csr, v, 2, 7, 2, &mut buf);
            assert_eq!(&blk.leaf[q * 2..(q + 1) * 2], &buf[..],
                       "leaf row {q}");
        }
    }

    /// Baseline hop-2 samples for a frontier node must equal the fused
    /// kernel's hop-2 samples for the same node (paired comparisons).
    #[test]
    fn baseline_and_fused_draw_identical_neighborhoods() {
        let csr = test_graph();
        let seeds = [5i32, 17, 333];
        let (k1, k2, base) = (4usize, 3usize, 97u64);
        let blk = build_block(&csr, &seeds, &Fanouts::of(&[k1, k2]), base);
        let s1 = sample_frontier(&csr, &seeds, k1, base, 0);
        let s2 = sample_frontier(&csr, &s1, k2, base, 1);
        let f1w = 1 + k1;
        for bi in 0..seeds.len() {
            for i in 0..k1 {
                // fused s2 row for (bi, i) == baseline leaf row for
                // frontier column 1+i
                let fused_row = &s2[(bi * k1 + i) * k2..][..k2];
                let base_row = &blk.leaf[(bi * f1w + 1 + i) * k2..][..k2];
                assert_eq!(fused_row, base_row);
            }
        }
    }

    #[test]
    fn pair_counting() {
        assert_eq!(valid_pairs(&[1, -1, 3, 3]), 3);
        assert_eq!(distinct_nodes(&[1, -1, 3, 3]), 2);
        let csr = test_graph();
        let seeds = [1i32, 2, 3, 4];
        for fo in [Fanouts::of(&[3]), Fanouts::of(&[3, 2]),
                   Fanouts::of(&[3, 2, 2])] {
            let blk = build_block(&csr, &seeds, &fo, 42);
            let raw = block_sampled_pairs(&blk);
            let cap: u64 = (0..fo.depth())
                .map(|l| (4 * fo.frontier_width(l) * fo.k(l)) as u64)
                .sum();
            assert!(raw > 0 && raw <= cap, "{fo}: {raw} > cap {cap}");
            let fused = fused_sampled_pairs(&csr, &seeds, &fo, 42);
            assert!(fused <= raw, "{fo}: fused {fused} > baseline {raw}");
        }
    }

    /// Property test: every sampled id is a real neighbor, padding is only
    /// where the rule says, and deg>k slots follow the counter formula.
    #[test]
    fn prop_sampling_rule_holds() {
        let csr = test_graph();
        let mut r = SplitMix64::new(31);
        for _ in 0..300 {
            let u = r.next_below(csr.n as u64) as i32;
            let k = 1 + r.next_below(8) as usize;
            let base = r.next_u64();
            let mut out = vec![0i32; k];
            sample_neighbors(&csr, u, k, base, 0, &mut out);
            let deg = csr.degree(u) as usize;
            let ns = csr.neighbors(u);
            if deg == 0 {
                assert!(out.iter().all(|&v| v == -1));
            } else if deg <= k {
                assert_eq!(&out[..deg], ns);
                assert!(out[deg..].iter().all(|&v| v == -1));
            } else {
                for (i, &v) in out.iter().enumerate() {
                    let rr = rand_counter(base, u as u64, 0, i as u64);
                    assert_eq!(v, ns[(rr % deg as u64) as usize]);
                }
            }
        }
    }
}
