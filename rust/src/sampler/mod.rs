//! Host-side neighbor sampling — the DGL `NeighborSampler` analogue.
//!
//! The baseline pipeline (sample → materialize → aggregate) runs this
//! sampler on the host, uploads the index tensors, and lets the baseline
//! executable gather/aggregate — exactly the structure the paper attacks.
//!
//! The sampling rule is the counter-hash rule of DESIGN.md §5, implemented
//! bit-for-bit like the Pallas kernel (`python/compile/kernels/sampling.py`)
//! so both variants draw identical neighborhoods; parity is pinned by golden
//! vectors generated from the python oracle and by the integration tests.
//!
//! [`reservoir`] provides the paper's Alg. 1 uniform-without-replacement
//! sampler (used for validation; see the substitution note in DESIGN.md §3).
//! [`parallel`] shards the frontier across a scoped-thread worker pool —
//! bitwise identical output at any thread count.

pub mod parallel;
pub mod reservoir;

pub use parallel::ParallelSampler;

use crate::graph::Csr;
use crate::rng::rand_counter;

/// Sample up to `k` neighbors of `node` into `out[..k]` (-1 padded).
///
/// Rule: invalid node / deg==0 → all -1; deg<=k → take-all in CSR order;
/// deg>k → slot i takes `col[start + rand(base,node,hop,i) % deg]`.
pub fn sample_neighbors(csr: &Csr, node: i32, k: usize, base: u64, hop: u64,
                        out: &mut [i32]) {
    debug_assert!(out.len() >= k);
    if node < 0 {
        out[..k].fill(-1);
        return;
    }
    let start = csr.rowptr[node as usize] as usize;
    let deg = csr.degree(node) as usize;
    if deg == 0 {
        out[..k].fill(-1);
        return;
    }
    if deg <= k {
        for i in 0..k {
            out[i] = if i < deg { csr.col[start + i] } else { -1 };
        }
        return;
    }
    for (i, o) in out.iter_mut().take(k).enumerate() {
        let r = rand_counter(base, node as u64, hop, i as u64);
        *o = csr.col[start + (r % deg as u64) as usize];
    }
}

/// Sample `k` neighbors for every node of a frontier; returns row-major
/// `[frontier.len(), k]`, -1 padded.
pub fn sample_frontier(csr: &Csr, frontier: &[i32], k: usize, base: u64,
                       hop: u64) -> Vec<i32> {
    let mut out = vec![-1i32; frontier.len() * k];
    for (i, &u) in frontier.iter().enumerate() {
        sample_neighbors(csr, u, k, base, hop, &mut out[i * k..(i + 1) * k]);
    }
    out
}

/// The index tensors one baseline 2-hop step uploads (DGL's "blocks").
pub struct Block2 {
    /// `[B, 1+k1]` frontier: column 0 = seed, columns 1.. = hop-1 samples.
    pub f1: Vec<i32>,
    /// `[B, 1+k1, k2]` hop-2 samples for every frontier node.
    pub s2: Vec<i32>,
    pub batch: usize,
    pub k1: usize,
    pub k2: usize,
}

/// The index tensor a baseline 1-hop step uploads.
pub struct Block1 {
    /// `[B, 1+k]` frontier: column 0 = seed, columns 1.. = samples.
    pub f1: Vec<i32>,
    pub batch: usize,
    pub k: usize,
}

/// Build the 2-layer frontier + blocks for a batch of seeds (no dedup —
/// static shapes; DESIGN.md §10 discusses the deviation from DGL's MFGs).
pub fn build_block2(csr: &Csr, seeds: &[i32], k1: usize, k2: usize,
                    base: u64) -> Block2 {
    let b = seeds.len();
    let f1w = 1 + k1;
    let mut f1 = vec![-1i32; b * f1w];
    for (bi, &r) in seeds.iter().enumerate() {
        f1[bi * f1w] = r;
        sample_neighbors(csr, r, k1, base, 0,
                         &mut f1[bi * f1w + 1..(bi + 1) * f1w]);
    }
    let s2 = sample_frontier(csr, &f1, k2, base, 1);
    Block2 { f1, s2, batch: b, k1, k2 }
}

/// Build the 1-layer frontier for a batch of seeds.
pub fn build_block1(csr: &Csr, seeds: &[i32], k: usize, base: u64) -> Block1 {
    let b = seeds.len();
    let f1w = 1 + k;
    let mut f1 = vec![-1i32; b * f1w];
    for (bi, &r) in seeds.iter().enumerate() {
        f1[bi * f1w] = r;
        sample_neighbors(csr, r, k, base, 0,
                         &mut f1[bi * f1w + 1..(bi + 1) * f1w]);
    }
    Block1 { f1, batch: b, k }
}

/// Count of valid (non `-1`) entries — the paper's raw "sampled pairs" unit.
pub fn valid_pairs(indices: &[i32]) -> u64 {
    indices.iter().filter(|&&v| v >= 0).count() as u64
}

/// Distinct valid ids — DGL's de-duplicated "block edges" style unit
/// (reported alongside for the Threats-to-Validity comparison).
pub fn distinct_nodes(indices: &[i32]) -> u64 {
    let mut ids: Vec<i32> = indices.iter().copied().filter(|&v| v >= 0).collect();
    ids.sort_unstable();
    ids.dedup();
    ids.len() as u64
}

/// Raw sampled pairs of one *fused* 2-hop step (B·k1 hop-1 draws plus the
/// valid hop-2 draws), computable without running the kernel because the
/// host sampler is bitwise-identical to it.
pub fn fused2_sampled_pairs(csr: &Csr, seeds: &[i32], k1: usize, k2: usize,
                            base: u64) -> u64 {
    let s1 = sample_frontier(csr, seeds, k1, base, 0);
    let s2 = sample_frontier(csr, &s1, k2, base, 1);
    valid_pairs(&s1) + valid_pairs(&s2)
}

/// Raw sampled pairs of one baseline 2-hop step (the frontier includes the
/// seed itself, so the baseline genuinely samples more pairs).
pub fn block2_sampled_pairs(block: &Block2) -> u64 {
    let f1w = 1 + block.k1;
    let hop1: u64 = (0..block.batch)
        .map(|bi| valid_pairs(&block.f1[bi * f1w + 1..(bi + 1) * f1w]))
        .sum();
    hop1 + valid_pairs(&block.s2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{builtin_spec, Dataset};
    use crate::rng::SplitMix64;

    fn test_graph() -> Csr {
        Dataset::generate(builtin_spec("tiny").unwrap()).unwrap().graph
    }

    #[test]
    fn take_all_when_degree_small() {
        let csr = Csr::from_edges(4, &[(0, 1), (0, 2)], 16, true).unwrap();
        let mut out = [0i32; 5];
        sample_neighbors(&csr, 0, 5, 42, 0, &mut out);
        assert_eq!(&out[..2], &[1, 2]);
        assert_eq!(&out[2..], &[-1, -1, -1]);
    }

    #[test]
    fn isolated_and_invalid_nodes_pad() {
        let csr = Csr::from_edges(4, &[(0, 1)], 16, true).unwrap();
        let mut out = [7i32; 3];
        sample_neighbors(&csr, 2, 3, 42, 0, &mut out);
        assert_eq!(out, [-1, -1, -1]);
        sample_neighbors(&csr, -1, 3, 42, 0, &mut out);
        assert_eq!(out, [-1, -1, -1]);
    }

    #[test]
    fn samples_are_neighbors_and_deterministic() {
        let csr = test_graph();
        let mut a = vec![0i32; 4];
        let mut b = vec![0i32; 4];
        for u in 0..csr.n as i32 {
            sample_neighbors(&csr, u, 4, 7, 0, &mut a);
            for &v in &a {
                if v >= 0 {
                    assert!(csr.neighbors(u).contains(&v));
                }
            }
            sample_neighbors(&csr, u, 4, 7, 0, &mut b);
            assert_eq!(a, b, "non-deterministic for node {u}");
        }
    }

    #[test]
    fn base_seed_changes_samples() {
        let csr = test_graph();
        // find a node with degree > k so the random path is taken
        let u = (0..csr.n as i32).find(|&u| csr.degree(u) > 3).unwrap();
        let mut a = vec![0i32; 3];
        let mut b = vec![0i32; 3];
        sample_neighbors(&csr, u, 3, 1, 0, &mut a);
        sample_neighbors(&csr, u, 3, 2, 0, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn block2_layout_embeds_seed_and_hop1() {
        let csr = test_graph();
        let seeds = [3i32, 100, 200];
        let blk = build_block2(&csr, &seeds, 4, 2, 42);
        let f1w = 5;
        for (bi, &r) in seeds.iter().enumerate() {
            assert_eq!(blk.f1[bi * f1w], r);
            let mut want = vec![0i32; 4];
            sample_neighbors(&csr, r, 4, 42, 0, &mut want);
            assert_eq!(&blk.f1[bi * f1w + 1..(bi + 1) * f1w], &want[..]);
        }
        assert_eq!(blk.s2.len(), 3 * f1w * 2);
    }

    /// Baseline hop-2 samples for a frontier node must equal the fused
    /// kernel's hop-2 samples for the same node (paired comparisons).
    #[test]
    fn baseline_and_fused_draw_identical_neighborhoods() {
        let csr = test_graph();
        let seeds = [5i32, 17, 333];
        let (k1, k2, base) = (4usize, 3usize, 97u64);
        let blk = build_block2(&csr, &seeds, k1, k2, base);
        let s1 = sample_frontier(&csr, &seeds, k1, base, 0);
        let s2 = sample_frontier(&csr, &s1, k2, base, 1);
        let f1w = 1 + k1;
        for bi in 0..seeds.len() {
            for i in 0..k1 {
                // fused s2 row for (bi, i) == baseline s2 row for frontier
                // column 1+i
                let fused_row = &s2[(bi * k1 + i) * k2..][..k2];
                let base_row = &blk.s2[(bi * f1w + 1 + i) * k2..][..k2];
                assert_eq!(fused_row, base_row);
            }
        }
    }

    #[test]
    fn pair_counting() {
        assert_eq!(valid_pairs(&[1, -1, 3, 3]), 3);
        assert_eq!(distinct_nodes(&[1, -1, 3, 3]), 2);
        let csr = test_graph();
        let seeds = [1i32, 2, 3, 4];
        let blk = build_block2(&csr, &seeds, 3, 2, 42);
        let raw = block2_sampled_pairs(&blk);
        assert!(raw > 0 && raw <= (4 * 3 + 4 * 4 * 2) as u64);
        let fused = fused2_sampled_pairs(&csr, &seeds, 3, 2, 42);
        assert!(fused <= raw, "fused {fused} > baseline {raw}");
    }

    /// Property test: every sampled id is a real neighbor, padding is only
    /// where the rule says, and deg>k slots follow the counter formula.
    #[test]
    fn prop_sampling_rule_holds() {
        let csr = test_graph();
        let mut r = SplitMix64::new(31);
        for _ in 0..300 {
            let u = r.next_below(csr.n as u64) as i32;
            let k = 1 + r.next_below(8) as usize;
            let base = r.next_u64();
            let mut out = vec![0i32; k];
            sample_neighbors(&csr, u, k, base, 0, &mut out);
            let deg = csr.degree(u) as usize;
            let ns = csr.neighbors(u);
            if deg == 0 {
                assert!(out.iter().all(|&v| v == -1));
            } else if deg <= k {
                assert_eq!(&out[..deg], ns);
                assert!(out[deg..].iter().all(|&v| v == -1));
            } else {
                for (i, &v) in out.iter().enumerate() {
                    let rr = rand_counter(base, u as u64, 0, i as u64);
                    assert_eq!(v, ns[(rr % deg as u64) as usize]);
                }
            }
        }
    }
}
