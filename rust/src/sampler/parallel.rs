//! Multi-threaded frontier sampler — the parallel half of the host
//! pipeline (SALIENT's "parallel batch preparation", arXiv 2110.08450,
//! applied to this repo's counter-RNG sampler).
//!
//! Because [`crate::rng::rand_counter`] is a pure function of
//! `(base, node, hop, slot)`, every output cell of a frontier sample is
//! independent of evaluation order. The parallel sampler therefore only
//! has to preserve the *write layout*: the frontier is cut into
//! contiguous, degree-balanced shards ([`crate::graph::shard`]), each
//! worker fills a disjoint `&mut` slice of the output tensor, and the
//! result is **bitwise identical** to the serial sampler at any thread
//! count (pinned by the tests below and `rust/tests/pipeline.rs`).
//!
//! Depth-generic: [`ParallelSampler::build_block`] runs the same
//! level-by-level expansion as the serial [`super::build_block`], each
//! level sharded independently.
//!
//! Workers are scoped threads spawned per call — a hand-rolled fork/join
//! pool with no queue, no locks, and no `unsafe`; for the frontier sizes
//! of the paper's grid (≥ 512 rows × 11–16 columns) the spawn cost is
//! well under the sampling work per shard. Tiny frontiers fall back to
//! the serial path via [`MIN_ROWS_PER_WORKER`].

use std::sync::{Arc, Mutex};

use crate::fanout::Fanouts;
use crate::graph::{lock_model, shard, CostModel, Csr, ImbalanceAcc,
                   PlannerChoice, ShardClock, ShardStats, SharedCostModel,
                   WallClock};
use crate::metrics::Timer;
use crate::runtime::faults::{self, Fault, FaultPlane, FaultSite};

use super::{sample_neighbors, Block};

/// Below this many frontier rows per worker, thread spawn overhead beats
/// the parallel speedup and the sampler degrades to fewer workers (the
/// output is identical either way).
pub const MIN_ROWS_PER_WORKER: usize = 64;

/// A frontier sampler running on `threads` scoped workers.
///
/// Per-level planning uses the *exact* row cost `1 + min(deg, k)` (a
/// frontier row's work is its own draws; there is no subtree below it in
/// the same tensor — see [`CostModel::frontier_cost`]). Nominal and
/// quantile plans are therefore identical here, so only the adaptive
/// flavor routes through a [`CostModel`]. When a [`SharedCostModel`] is
/// attached ([`ParallelSampler::with_model`]), every sharded level's
/// measured [`ShardStats`] is folded back into that model via
/// [`CostModel::observe`] — the block/baseline sampler adapts through
/// the *same* per-worker weights as the fused kernel, instead of
/// discarding what it measures. Every sharded pass also contributes its
/// wall time to an [`ImbalanceAcc`] drained by
/// [`ParallelSampler::take_imbalance`]; passes of different worker
/// counts (the levels of one block build) aggregate by
/// critical-path-over-ideal, not by per-shard vectors. Per-shard timing
/// goes through an injectable [`ShardClock`] ([`WallClock`] by default;
/// tests script a deterministic virtual clock).
#[derive(Clone, Debug)]
pub struct ParallelSampler {
    threads: usize,
    planner: PlannerChoice,
    /// Imbalance accumulator (`Arc`: clones share it, like the stats of
    /// one pipeline stage).
    stats: Arc<Mutex<ImbalanceAcc>>,
    /// Session-shared planner model (adaptive feedback; None = plan
    /// standalone and discard the measured stats beyond the Acc).
    model: Option<SharedCostModel>,
    /// Timing seam for the sharded passes.
    clock: Arc<dyn ShardClock>,
    /// Fault seam for the sharded passes (no-op plane in production).
    faults: Arc<dyn FaultPlane>,
}

impl ParallelSampler {
    /// `threads == 0` selects the machine's available parallelism.
    pub fn new(threads: usize) -> Self {
        Self::with_planner(threads, PlannerChoice::default())
    }

    /// [`ParallelSampler::new`] with an explicit planner flavor.
    pub fn with_planner(threads: usize, planner: PlannerChoice) -> Self {
        let t = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        ParallelSampler {
            threads: t.max(1),
            planner,
            stats: Arc::new(Mutex::new(ImbalanceAcc::default())),
            model: None,
            clock: Arc::new(WallClock),
            faults: faults::none(),
        }
    }

    /// Attach the session's shared planner model: block builds plan
    /// through it and fold their measured per-level [`ShardStats`] back
    /// via [`CostModel::observe`] (the sampler half of the adaptive
    /// feedback loop). The sampler also adopts the model's clock and
    /// fault plane so one seam scripts both the kernel's and the
    /// sampler's timing and faults.
    pub fn with_model(mut self, model: SharedCostModel) -> Self {
        let m = lock_model(&model);
        self.clock = m.clock();
        self.faults = m.faults();
        drop(m);
        self.model = Some(model);
        self
    }

    /// Replace the timing seam (tests script a virtual clock here).
    pub fn with_clock(mut self, clock: Arc<dyn ShardClock>) -> Self {
        self.clock = clock;
        self
    }

    /// Replace the fault seam (chaos runs and the fault-tolerance tests).
    pub fn with_faults(mut self, faults: Arc<dyn FaultPlane>) -> Self {
        self.faults = faults;
        self
    }

    /// The serial sampler (1 worker) as a `ParallelSampler`.
    pub fn serial() -> Self {
        Self::with_planner(1, PlannerChoice::default())
    }

    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// A sampler sharing this one's planner model and clock but with a
    /// fresh (empty) imbalance accumulator — the shape the prefetch
    /// worker needs: shared feedback, private per-batch stats.
    pub fn fresh_stats(&self) -> ParallelSampler {
        ParallelSampler {
            threads: self.threads,
            planner: self.planner,
            stats: Arc::new(Mutex::new(ImbalanceAcc::default())),
            model: self.model.clone(),
            clock: self.clock.clone(),
            faults: self.faults.clone(),
        }
    }

    /// Drain the accumulated measured imbalance ratio (None when every
    /// pass since the last drain ran serially).
    pub fn take_imbalance(&self) -> Option<f64> {
        let mut s = self.stats.lock().ok()?;
        if s.is_empty() {
            None
        } else {
            Some(std::mem::take(&mut *s).imbalance())
        }
    }

    /// Fold one sharded pass into the accumulator and, when a shared
    /// model is attached, into its adaptive per-worker weights — the
    /// sampler half of the measured feedback loop.
    fn record(&self, stats: ShardStats) {
        if stats.is_empty() {
            return;
        }
        if let Ok(mut s) = self.stats.lock() {
            s.add(&stats);
        }
        if let Some(m) = &self.model {
            lock_model(m).observe(&stats);
        }
    }

    /// Workers actually worth spawning for a frontier of `rows` rows.
    fn workers_for(&self, rows: usize) -> usize {
        self.threads.min((rows / MIN_ROWS_PER_WORKER).max(1))
    }

    /// Run `fill(node, out_row)` over the planned contiguous shards of
    /// `frontier`, each worker owning a disjoint `width`-column slice of
    /// `out`. `costs` are the planner's per-row costs (aligned with
    /// `frontier`); per-shard wall time is measured through the clock
    /// seam and recorded — with the planned shard costs — into the
    /// accumulator and the shared model.
    fn run_plan<F>(&self, frontier: &[i32], width: usize, out: &mut [i32],
                   plan: Vec<std::ops::Range<usize>>, costs: &[u64], fill: F)
    where
        F: Fn(i32, &mut [i32]) + Sync,
    {
        let mut shard_ms = vec![0.0f64; plan.len()];
        let shard_cost: Vec<u64> = plan
            .iter()
            .map(|r| costs[r.clone()].iter().sum())
            .collect();
        let pass = self.faults.begin(FaultSite::SamplerWorker);
        let plan_ranges = plan.clone();
        let mut failed = vec![false; plan_ranges.len()];
        std::thread::scope(|s| {
            let mut rest: &mut [i32] = &mut *out;
            let mut ms_rest: &mut [f64] = &mut shard_ms;
            let mut failed_rest: &mut [bool] = &mut failed;
            let fill = &fill;
            for (j, r) in plan.into_iter().enumerate() {
                let take = (r.end - r.start) * width;
                let slab = std::mem::take(&mut rest);
                let (chunk, tail) = slab.split_at_mut(take);
                rest = tail;
                let (ms_c, tail) = std::mem::take(&mut ms_rest).split_at_mut(1);
                ms_rest = tail;
                let (fail_c, tail) =
                    std::mem::take(&mut failed_rest).split_at_mut(1);
                failed_rest = tail;
                let rows = &frontier[r];
                if rows.is_empty() {
                    continue;
                }
                let clock = self.clock.clone();
                let faults = self.faults.clone();
                let cost_j = shard_cost[j];
                s.spawn(move || {
                    let t = Timer::start();
                    let res = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| {
                            match faults.fault(FaultSite::SamplerWorker,
                                               pass, j) {
                                Fault::Stall(ms) => std::thread::sleep(
                                    std::time::Duration::from_millis(ms)),
                                Fault::Panic | Fault::Error => {
                                    panic!("chaos: injected sampler panic \
                                            (op {pass}, worker {j})")
                                }
                                _ => {}
                            }
                            for (i, &u) in rows.iter().enumerate() {
                                fill(u,
                                     &mut chunk[i * width..(i + 1) * width]);
                            }
                        }));
                    fail_c[0] = res.is_err();
                    ms_c[0] = clock.shard_ms(j, cost_j, t.ms());
                });
            }
        });
        // Recovery: redo any panicked shard serially — the counter RNG
        // is stateless, so the redo is bitwise identical to an
        // undisturbed pass over those rows.
        for (j, r) in plan_ranges.iter().enumerate() {
            if !failed[j] {
                continue;
            }
            eprintln!("warning: sampler shard worker {j} panicked; \
                       resampling rows {}..{} serially", r.start, r.end);
            let chunk = &mut out[r.start * width..r.end * width];
            chunk.fill(-1);
            for (i, &u) in frontier[r.clone()].iter().enumerate() {
                fill(u, &mut chunk[i * width..(i + 1) * width]);
            }
        }
        self.record(ShardStats::new(shard_ms, shard_cost));
    }

    /// Plan one frontier level from the exact per-row cost
    /// `1 + min(deg, k)`. With a model (the adaptive block path) the
    /// cuts route through its measured per-worker weights; the per-row
    /// costs come back alongside the plan so the executed shards can be
    /// costed for the feedback observation.
    fn level_plan(&self, csr: &Csr, frontier: &[i32], k: usize, hop: usize,
                  workers: usize, model: Option<&CostModel>)
                  -> (Vec<u64>, Vec<std::ops::Range<usize>>) {
        let costs: Vec<u64> = match model {
            Some(m) => frontier
                .iter()
                .map(|&u| m.frontier_cost(csr, u, hop))
                .collect(),
            None => frontier
                .iter()
                .map(|&u| shard::sample_cost(csr, u, k))
                .collect(),
        };
        let plan = match model {
            Some(m) => m.plan(&costs, workers),
            None => shard::plan_shards(&costs, workers),
        };
        (costs, plan)
    }

    /// Parallel [`super::sample_frontier`]: row-major `[frontier.len(), k]`,
    /// -1 padded, bitwise identical to the serial path.
    pub fn sample_frontier(&self, csr: &Csr, frontier: &[i32], k: usize,
                           base: u64, hop: u64) -> Vec<i32> {
        self.sample_frontier_planned(csr, frontier, k, base, hop, None)
    }

    fn sample_frontier_planned(&self, csr: &Csr, frontier: &[i32], k: usize,
                               base: u64, hop: u64,
                               model: Option<&CostModel>) -> Vec<i32> {
        let workers = self.workers_for(frontier.len());
        if workers == 1 || k == 0 {
            return super::sample_frontier(csr, frontier, k, base, hop);
        }
        let mut out = vec![-1i32; frontier.len() * k];
        let (costs, plan) =
            self.level_plan(csr, frontier, k, hop as usize, workers, model);
        self.run_plan(frontier, k, &mut out, plan, &costs, |u, row| {
            sample_neighbors(csr, u, k, base, hop, row);
        });
        out
    }

    /// Parallel [`super::expand_frontier`]: `[nodes.len(), 1 + k]` with
    /// column 0 the node itself and columns 1.. its hop-`hop` samples.
    pub fn expand_frontier(&self, csr: &Csr, nodes: &[i32], k: usize,
                           base: u64, hop: u64) -> Vec<i32> {
        self.expand_frontier_planned(csr, nodes, k, base, hop, None)
    }

    fn expand_frontier_planned(&self, csr: &Csr, nodes: &[i32], k: usize,
                               base: u64, hop: u64,
                               model: Option<&CostModel>) -> Vec<i32> {
        let w = 1 + k;
        let workers = self.workers_for(nodes.len());
        if workers == 1 {
            return super::expand_frontier(csr, nodes, k, base, hop);
        }
        let mut out = vec![-1i32; nodes.len() * w];
        let (costs, plan) =
            self.level_plan(csr, nodes, k, hop as usize, workers, model);
        self.run_plan(nodes, w, &mut out, plan, &costs, |u, row| {
            row[0] = u;
            sample_neighbors(csr, u, k, base, hop, &mut row[1..]);
        });
        out
    }

    /// Parallel [`super::build_block`] (bitwise identical at any thread
    /// count and planner flavor): the same level-by-level expansion, each
    /// level sharded by its exact per-row costs. Only the adaptive flavor
    /// plans through a [`CostModel`] — nominal/quantile plans are
    /// provably the same as the exact path, and skipping the model keeps
    /// the default block pipeline from building the degree sketch it
    /// never reads. With an attached [`SharedCostModel`] the levels plan
    /// from a snapshot of the shared weights (this build's observations
    /// shift the *next* build's cuts, one feedback step per batch).
    pub fn build_block(&self, csr: &Csr, seeds: &[i32], fanouts: &Fanouts,
                       base: u64) -> Block {
        if self.threads == 1 {
            return super::build_block(csr, seeds, fanouts, base);
        }
        let model: Option<CostModel> = match &self.model {
            Some(shared) => Some(lock_model(shared).clone()),
            None => (self.planner == PlannerChoice::Adaptive)
                .then(|| CostModel::new(csr, fanouts, self.planner)),
        };
        let depth = fanouts.depth();
        let mut frontiers: Vec<Vec<i32>> = Vec::with_capacity(depth);
        frontiers.push(seeds.to_vec());
        for hop in 0..depth - 1 {
            let next = self.expand_frontier_planned(
                csr, &frontiers[hop], fanouts.k(hop), base, hop as u64,
                model.as_ref());
            frontiers.push(next);
        }
        let leaf = self.sample_frontier_planned(
            csr, &frontiers[depth - 1], fanouts.k(depth - 1), base,
            (depth - 1) as u64, model.as_ref());
        Block {
            batch: seeds.len(),
            fanouts: fanouts.clone(),
            frontiers,
            leaf,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{builtin_spec, Dataset};
    use crate::rng::SplitMix64;

    fn test_graph() -> Csr {
        Dataset::generate(builtin_spec("tiny").unwrap()).unwrap().graph
    }

    fn random_seeds(csr: &Csr, n: usize, seed: u64) -> Vec<i32> {
        let mut r = SplitMix64::new(seed);
        (0..n).map(|_| r.next_below(csr.n as u64) as i32).collect()
    }

    #[test]
    fn frontier_bitwise_identical_across_thread_counts() {
        let csr = test_graph();
        // include invalid rows like a padded frontier would
        let mut frontier = random_seeds(&csr, 400, 3);
        frontier[7] = -1;
        frontier[123] = -1;
        let serial = crate::sampler::sample_frontier(&csr, &frontier, 5, 99, 1);
        for threads in [1usize, 2, 3, 8, 16] {
            let par = ParallelSampler::new(threads)
                .sample_frontier(&csr, &frontier, 5, 99, 1);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn block_bitwise_identical_across_thread_counts_and_depths() {
        let csr = test_graph();
        let seeds = random_seeds(&csr, 256, 11);
        for fo in [Fanouts::of(&[6]), Fanouts::of(&[4, 3]),
                   Fanouts::of(&[4, 3, 2])] {
            let serial = crate::sampler::build_block(&csr, &seeds, &fo, 42);
            for threads in [1usize, 2, 8] {
                let par = ParallelSampler::new(threads)
                    .build_block(&csr, &seeds, &fo, 42);
                assert_eq!(par.frontiers, serial.frontiers,
                           "{fo}: frontiers differ at threads={threads}");
                assert_eq!(par.leaf, serial.leaf,
                           "{fo}: leaf differs at threads={threads}");
                assert_eq!((par.batch, &par.fanouts),
                           (serial.batch, &serial.fanouts));
            }
        }
    }

    #[test]
    fn tiny_frontiers_take_the_serial_path() {
        let csr = test_graph();
        let seeds = random_seeds(&csr, 8, 5);
        let s = ParallelSampler::new(8);
        assert_eq!(s.workers_for(seeds.len()), 1);
        let fo = Fanouts::of(&[3, 2]);
        let serial = crate::sampler::build_block(&csr, &seeds, &fo, 1);
        let par = s.build_block(&csr, &seeds, &fo, 1);
        assert_eq!(par.frontiers, serial.frontiers);
        assert_eq!(par.leaf, serial.leaf);
    }

    #[test]
    fn zero_threads_means_auto() {
        assert!(ParallelSampler::new(0).threads() >= 1);
        assert_eq!(ParallelSampler::serial().threads(), 1);
    }

    /// Property: random frontiers, fanouts, and thread counts always match
    /// the serial sampler bitwise.
    #[test]
    fn prop_parallel_matches_serial() {
        let csr = test_graph();
        let mut r = SplitMix64::new(77);
        for _ in 0..25 {
            let n = 65 + r.next_below(400) as usize;
            let k = 1 + r.next_below(8) as usize;
            let base = r.next_u64();
            let frontier = random_seeds(&csr, n, r.next_u64());
            let serial =
                crate::sampler::sample_frontier(&csr, &frontier, k, base, 0);
            let threads = 1 + r.next_below(8) as usize;
            let par = ParallelSampler::new(threads)
                .sample_frontier(&csr, &frontier, k, base, 0);
            assert_eq!(par, serial, "n={n} k={k} threads={threads}");
        }
    }
}
