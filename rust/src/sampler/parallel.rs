//! Multi-threaded frontier sampler — the parallel half of the host
//! pipeline (SALIENT's "parallel batch preparation", arXiv 2110.08450,
//! applied to this repo's counter-RNG sampler).
//!
//! Because [`crate::rng::rand_counter`] is a pure function of
//! `(base, node, hop, slot)`, every output cell of a frontier sample is
//! independent of evaluation order. The parallel sampler therefore only
//! has to preserve the *write layout*: the frontier is cut into
//! contiguous, degree-balanced shards ([`crate::graph::shard`]), each
//! worker fills a disjoint `&mut` slice of the output tensor, and the
//! result is **bitwise identical** to the serial sampler at any thread
//! count (pinned by the tests below and `rust/tests/pipeline.rs`).
//!
//! Depth-generic: [`ParallelSampler::build_block`] runs the same
//! level-by-level expansion as the serial [`super::build_block`], each
//! level sharded independently.
//!
//! Workers are scoped threads spawned per call — a hand-rolled fork/join
//! pool with no queue, no locks, and no `unsafe`; for the frontier sizes
//! of the paper's grid (≥ 512 rows × 11–16 columns) the spawn cost is
//! well under the sampling work per shard. Tiny frontiers fall back to
//! the serial path via [`MIN_ROWS_PER_WORKER`].

use std::sync::{Arc, Mutex};

use crate::fanout::Fanouts;
use crate::graph::{shard, CostModel, Csr, ImbalanceAcc, PlannerChoice};
use crate::metrics::Timer;

use super::{sample_neighbors, Block};

/// Below this many frontier rows per worker, thread spawn overhead beats
/// the parallel speedup and the sampler degrades to fewer workers (the
/// output is identical either way).
pub const MIN_ROWS_PER_WORKER: usize = 64;

/// A frontier sampler running on `threads` scoped workers.
///
/// Per-level planning uses the *exact* row cost `1 + min(deg, k)` (a
/// frontier row's work is its own draws; there is no subtree below it in
/// the same tensor — see [`CostModel::frontier_cost`]). Nominal and
/// quantile plans are therefore identical here, so only the adaptive
/// flavor routes through a [`CostModel`] (whose weighted cut targets the
/// ROADMAP follow-on will feed from sampler stats). Every sharded pass
/// contributes its wall time to an [`ImbalanceAcc`] drained by
/// [`ParallelSampler::take_imbalance`] — the sampler half of the
/// measured-imbalance feedback loop; passes of different worker counts
/// (the levels of one block build) aggregate by
/// critical-path-over-ideal, not by per-shard vectors.
#[derive(Clone, Debug)]
pub struct ParallelSampler {
    threads: usize,
    planner: PlannerChoice,
    /// Imbalance accumulator (`Arc`: clones share it, like the stats of
    /// one pipeline stage).
    stats: Arc<Mutex<ImbalanceAcc>>,
}

impl ParallelSampler {
    /// `threads == 0` selects the machine's available parallelism.
    pub fn new(threads: usize) -> Self {
        Self::with_planner(threads, PlannerChoice::default())
    }

    /// [`ParallelSampler::new`] with an explicit planner flavor.
    pub fn with_planner(threads: usize, planner: PlannerChoice) -> Self {
        let t = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        ParallelSampler {
            threads: t.max(1),
            planner,
            stats: Arc::new(Mutex::new(ImbalanceAcc::default())),
        }
    }

    /// The serial sampler (1 worker) as a `ParallelSampler`.
    pub fn serial() -> Self {
        Self::with_planner(1, PlannerChoice::default())
    }

    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Drain the accumulated measured imbalance ratio (None when every
    /// pass since the last drain ran serially).
    pub fn take_imbalance(&self) -> Option<f64> {
        let mut s = self.stats.lock().ok()?;
        if s.is_empty() {
            None
        } else {
            Some(std::mem::take(&mut *s).imbalance())
        }
    }

    fn record(&self, shard_ms: &[f64]) {
        let parts = shard_ms.len();
        if parts == 0 {
            return;
        }
        let crit = shard_ms.iter().fold(0.0f64, |m, &v| m.max(v));
        let ideal = shard_ms.iter().sum::<f64>() / parts as f64;
        if let Ok(mut s) = self.stats.lock() {
            s.add_pass(crit, ideal);
        }
    }

    /// Workers actually worth spawning for a frontier of `rows` rows.
    fn workers_for(&self, rows: usize) -> usize {
        self.threads.min((rows / MIN_ROWS_PER_WORKER).max(1))
    }

    /// Run `fill(node, out_row)` over the planned contiguous shards of
    /// `frontier`, each worker owning a disjoint `width`-column slice of
    /// `out`; per-shard wall time is recorded into the accumulator.
    fn run_plan<F>(&self, frontier: &[i32], width: usize, out: &mut [i32],
                   plan: Vec<std::ops::Range<usize>>, fill: F)
    where
        F: Fn(i32, &mut [i32]) + Sync,
    {
        let mut shard_ms = vec![0.0f64; plan.len()];
        std::thread::scope(|s| {
            let mut rest: &mut [i32] = out;
            let mut ms_rest: &mut [f64] = &mut shard_ms;
            let fill = &fill;
            for r in plan {
                let take = (r.end - r.start) * width;
                let slab = std::mem::take(&mut rest);
                let (chunk, tail) = slab.split_at_mut(take);
                rest = tail;
                let (ms_c, tail) = std::mem::take(&mut ms_rest).split_at_mut(1);
                ms_rest = tail;
                let rows = &frontier[r];
                if rows.is_empty() {
                    continue;
                }
                s.spawn(move || {
                    let t = Timer::start();
                    for (i, &u) in rows.iter().enumerate() {
                        fill(u, &mut chunk[i * width..(i + 1) * width]);
                    }
                    ms_c[0] = t.ms();
                });
            }
        });
        self.record(&shard_ms);
    }

    /// Plan one frontier level from the exact per-row cost
    /// `1 + min(deg, k)`. With a model (the adaptive block path) the
    /// costs and cuts route through it — today that produces identical
    /// cuts (a fresh model has no worker weights); it is the hook the
    /// sampler-feedback follow-on (ROADMAP) fills in.
    fn level_plan(&self, csr: &Csr, frontier: &[i32], k: usize, hop: usize,
                  workers: usize, model: Option<&CostModel>)
                  -> Vec<std::ops::Range<usize>> {
        let costs: Vec<u64> = match model {
            Some(m) => frontier
                .iter()
                .map(|&u| m.frontier_cost(csr, u, hop))
                .collect(),
            None => frontier
                .iter()
                .map(|&u| shard::sample_cost(csr, u, k))
                .collect(),
        };
        match model {
            Some(m) => m.plan(&costs, workers),
            None => shard::plan_shards(&costs, workers),
        }
    }

    /// Parallel [`super::sample_frontier`]: row-major `[frontier.len(), k]`,
    /// -1 padded, bitwise identical to the serial path.
    pub fn sample_frontier(&self, csr: &Csr, frontier: &[i32], k: usize,
                           base: u64, hop: u64) -> Vec<i32> {
        self.sample_frontier_planned(csr, frontier, k, base, hop, None)
    }

    fn sample_frontier_planned(&self, csr: &Csr, frontier: &[i32], k: usize,
                               base: u64, hop: u64,
                               model: Option<&CostModel>) -> Vec<i32> {
        let workers = self.workers_for(frontier.len());
        if workers == 1 || k == 0 {
            return super::sample_frontier(csr, frontier, k, base, hop);
        }
        let mut out = vec![-1i32; frontier.len() * k];
        let plan =
            self.level_plan(csr, frontier, k, hop as usize, workers, model);
        self.run_plan(frontier, k, &mut out, plan, |u, row| {
            sample_neighbors(csr, u, k, base, hop, row);
        });
        out
    }

    /// Parallel [`super::expand_frontier`]: `[nodes.len(), 1 + k]` with
    /// column 0 the node itself and columns 1.. its hop-`hop` samples.
    pub fn expand_frontier(&self, csr: &Csr, nodes: &[i32], k: usize,
                           base: u64, hop: u64) -> Vec<i32> {
        self.expand_frontier_planned(csr, nodes, k, base, hop, None)
    }

    fn expand_frontier_planned(&self, csr: &Csr, nodes: &[i32], k: usize,
                               base: u64, hop: u64,
                               model: Option<&CostModel>) -> Vec<i32> {
        let w = 1 + k;
        let workers = self.workers_for(nodes.len());
        if workers == 1 {
            return super::expand_frontier(csr, nodes, k, base, hop);
        }
        let mut out = vec![-1i32; nodes.len() * w];
        let plan =
            self.level_plan(csr, nodes, k, hop as usize, workers, model);
        self.run_plan(nodes, w, &mut out, plan, |u, row| {
            row[0] = u;
            sample_neighbors(csr, u, k, base, hop, &mut row[1..]);
        });
        out
    }

    /// Parallel [`super::build_block`] (bitwise identical at any thread
    /// count and planner flavor): the same level-by-level expansion, each
    /// level sharded by its exact per-row costs. Only the adaptive flavor
    /// builds a [`CostModel`] — nominal/quantile plans are provably the
    /// same as the exact path, and skipping the model keeps the default
    /// block pipeline from building the degree sketch it never reads.
    pub fn build_block(&self, csr: &Csr, seeds: &[i32], fanouts: &Fanouts,
                       base: u64) -> Block {
        if self.threads == 1 {
            return super::build_block(csr, seeds, fanouts, base);
        }
        let model = (self.planner == PlannerChoice::Adaptive)
            .then(|| CostModel::new(csr, fanouts, self.planner));
        let depth = fanouts.depth();
        let mut frontiers: Vec<Vec<i32>> = Vec::with_capacity(depth);
        frontiers.push(seeds.to_vec());
        for hop in 0..depth - 1 {
            let next = self.expand_frontier_planned(
                csr, &frontiers[hop], fanouts.k(hop), base, hop as u64,
                model.as_ref());
            frontiers.push(next);
        }
        let leaf = self.sample_frontier_planned(
            csr, &frontiers[depth - 1], fanouts.k(depth - 1), base,
            (depth - 1) as u64, model.as_ref());
        Block {
            batch: seeds.len(),
            fanouts: fanouts.clone(),
            frontiers,
            leaf,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{builtin_spec, Dataset};
    use crate::rng::SplitMix64;

    fn test_graph() -> Csr {
        Dataset::generate(builtin_spec("tiny").unwrap()).unwrap().graph
    }

    fn random_seeds(csr: &Csr, n: usize, seed: u64) -> Vec<i32> {
        let mut r = SplitMix64::new(seed);
        (0..n).map(|_| r.next_below(csr.n as u64) as i32).collect()
    }

    #[test]
    fn frontier_bitwise_identical_across_thread_counts() {
        let csr = test_graph();
        // include invalid rows like a padded frontier would
        let mut frontier = random_seeds(&csr, 400, 3);
        frontier[7] = -1;
        frontier[123] = -1;
        let serial = crate::sampler::sample_frontier(&csr, &frontier, 5, 99, 1);
        for threads in [1usize, 2, 3, 8, 16] {
            let par = ParallelSampler::new(threads)
                .sample_frontier(&csr, &frontier, 5, 99, 1);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn block_bitwise_identical_across_thread_counts_and_depths() {
        let csr = test_graph();
        let seeds = random_seeds(&csr, 256, 11);
        for fo in [Fanouts::of(&[6]), Fanouts::of(&[4, 3]),
                   Fanouts::of(&[4, 3, 2])] {
            let serial = crate::sampler::build_block(&csr, &seeds, &fo, 42);
            for threads in [1usize, 2, 8] {
                let par = ParallelSampler::new(threads)
                    .build_block(&csr, &seeds, &fo, 42);
                assert_eq!(par.frontiers, serial.frontiers,
                           "{fo}: frontiers differ at threads={threads}");
                assert_eq!(par.leaf, serial.leaf,
                           "{fo}: leaf differs at threads={threads}");
                assert_eq!((par.batch, &par.fanouts),
                           (serial.batch, &serial.fanouts));
            }
        }
    }

    #[test]
    fn tiny_frontiers_take_the_serial_path() {
        let csr = test_graph();
        let seeds = random_seeds(&csr, 8, 5);
        let s = ParallelSampler::new(8);
        assert_eq!(s.workers_for(seeds.len()), 1);
        let fo = Fanouts::of(&[3, 2]);
        let serial = crate::sampler::build_block(&csr, &seeds, &fo, 1);
        let par = s.build_block(&csr, &seeds, &fo, 1);
        assert_eq!(par.frontiers, serial.frontiers);
        assert_eq!(par.leaf, serial.leaf);
    }

    #[test]
    fn zero_threads_means_auto() {
        assert!(ParallelSampler::new(0).threads() >= 1);
        assert_eq!(ParallelSampler::serial().threads(), 1);
    }

    /// Property: random frontiers, fanouts, and thread counts always match
    /// the serial sampler bitwise.
    #[test]
    fn prop_parallel_matches_serial() {
        let csr = test_graph();
        let mut r = SplitMix64::new(77);
        for _ in 0..25 {
            let n = 65 + r.next_below(400) as usize;
            let k = 1 + r.next_below(8) as usize;
            let base = r.next_u64();
            let frontier = random_seeds(&csr, n, r.next_u64());
            let serial =
                crate::sampler::sample_frontier(&csr, &frontier, k, base, 0);
            let threads = 1 + r.next_below(8) as usize;
            let par = ParallelSampler::new(threads)
                .sample_frontier(&csr, &frontier, k, base, 0);
            assert_eq!(par, serial, "n={n} k={k} threads={threads}");
        }
    }
}
