//! Multi-threaded frontier sampler — the parallel half of the host
//! pipeline (SALIENT's "parallel batch preparation", arXiv 2110.08450,
//! applied to this repo's counter-RNG sampler).
//!
//! Because [`crate::rng::rand_counter`] is a pure function of
//! `(base, node, hop, slot)`, every output cell of a frontier sample is
//! independent of evaluation order. The parallel sampler therefore only
//! has to preserve the *write layout*: the frontier is cut into
//! contiguous, degree-balanced shards ([`crate::graph::shard`]), each
//! worker fills a disjoint `&mut` slice of the output tensor, and the
//! result is **bitwise identical** to the serial sampler at any thread
//! count (pinned by the tests below and `rust/tests/pipeline.rs`).
//!
//! Workers are scoped threads spawned per call — a hand-rolled fork/join
//! pool with no queue, no locks, and no `unsafe`; for the frontier sizes
//! of the paper's grid (≥ 512 rows × 11–16 columns) the spawn cost is
//! well under the sampling work per shard. Tiny frontiers fall back to
//! the serial path via [`MIN_ROWS_PER_WORKER`].

use crate::graph::{shard, Csr};

use super::{sample_neighbors, Block1, Block2};

/// Below this many frontier rows per worker, thread spawn overhead beats
/// the parallel speedup and the sampler degrades to fewer workers (the
/// output is identical either way).
pub const MIN_ROWS_PER_WORKER: usize = 64;

/// A frontier sampler running on `threads` scoped workers.
#[derive(Clone, Debug)]
pub struct ParallelSampler {
    threads: usize,
}

impl ParallelSampler {
    /// `threads == 0` selects the machine's available parallelism.
    pub fn new(threads: usize) -> Self {
        let t = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        ParallelSampler { threads: t.max(1) }
    }

    /// The serial sampler (1 worker) as a `ParallelSampler`.
    pub fn serial() -> Self {
        ParallelSampler { threads: 1 }
    }

    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Workers actually worth spawning for a frontier of `rows` rows.
    fn workers_for(&self, rows: usize) -> usize {
        self.threads.min((rows / MIN_ROWS_PER_WORKER).max(1))
    }

    /// Parallel [`super::sample_frontier`]: row-major `[frontier.len(), k]`,
    /// -1 padded, bitwise identical to the serial path.
    pub fn sample_frontier(&self, csr: &Csr, frontier: &[i32], k: usize,
                           base: u64, hop: u64) -> Vec<i32> {
        let workers = self.workers_for(frontier.len());
        if workers == 1 || k == 0 {
            return super::sample_frontier(csr, frontier, k, base, hop);
        }
        let mut out = vec![-1i32; frontier.len() * k];
        let plan = shard::plan_frontier_shards(csr, frontier, k, workers);
        std::thread::scope(|s| {
            let mut rest: &mut [i32] = &mut out;
            for r in plan {
                let take = (r.end - r.start) * k;
                let slab = std::mem::take(&mut rest);
                let (chunk, tail) = slab.split_at_mut(take);
                rest = tail;
                let rows = &frontier[r];
                if rows.is_empty() {
                    continue;
                }
                s.spawn(move || {
                    for (i, &u) in rows.iter().enumerate() {
                        sample_neighbors(csr, u, k, base, hop,
                                         &mut chunk[i * k..(i + 1) * k]);
                    }
                });
            }
        });
        out
    }

    /// Parallel frontier build: `[seeds.len(), 1 + k]` with column 0 the
    /// seed and columns 1.. its hop-0 samples (the `f1` layout).
    fn build_frontier(&self, csr: &Csr, seeds: &[i32], k: usize,
                      base: u64) -> Vec<i32> {
        let f1w = 1 + k;
        let mut f1 = vec![-1i32; seeds.len() * f1w];
        let workers = self.workers_for(seeds.len());
        if workers == 1 {
            for (bi, &r) in seeds.iter().enumerate() {
                f1[bi * f1w] = r;
                sample_neighbors(csr, r, k, base, 0,
                                 &mut f1[bi * f1w + 1..(bi + 1) * f1w]);
            }
            return f1;
        }
        let plan = shard::plan_frontier_shards(csr, seeds, k, workers);
        std::thread::scope(|s| {
            let mut rest: &mut [i32] = &mut f1;
            for r in plan {
                let take = (r.end - r.start) * f1w;
                let slab = std::mem::take(&mut rest);
                let (chunk, tail) = slab.split_at_mut(take);
                rest = tail;
                let rows = &seeds[r];
                if rows.is_empty() {
                    continue;
                }
                s.spawn(move || {
                    for (i, &u) in rows.iter().enumerate() {
                        chunk[i * f1w] = u;
                        sample_neighbors(csr, u, k, base, 0,
                                         &mut chunk[i * f1w + 1..(i + 1) * f1w]);
                    }
                });
            }
        });
        f1
    }

    /// Parallel [`super::build_block2`] (bitwise identical).
    pub fn build_block2(&self, csr: &Csr, seeds: &[i32], k1: usize, k2: usize,
                        base: u64) -> Block2 {
        if self.threads == 1 {
            return super::build_block2(csr, seeds, k1, k2, base);
        }
        let f1 = self.build_frontier(csr, seeds, k1, base);
        let s2 = self.sample_frontier(csr, &f1, k2, base, 1);
        Block2 { f1, s2, batch: seeds.len(), k1, k2 }
    }

    /// Parallel [`super::build_block1`] (bitwise identical).
    pub fn build_block1(&self, csr: &Csr, seeds: &[i32], k: usize,
                        base: u64) -> Block1 {
        if self.threads == 1 {
            return super::build_block1(csr, seeds, k, base);
        }
        Block1 {
            f1: self.build_frontier(csr, seeds, k, base),
            batch: seeds.len(),
            k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{builtin_spec, Dataset};
    use crate::rng::SplitMix64;

    fn test_graph() -> Csr {
        Dataset::generate(builtin_spec("tiny").unwrap()).unwrap().graph
    }

    fn random_seeds(csr: &Csr, n: usize, seed: u64) -> Vec<i32> {
        let mut r = SplitMix64::new(seed);
        (0..n).map(|_| r.next_below(csr.n as u64) as i32).collect()
    }

    #[test]
    fn frontier_bitwise_identical_across_thread_counts() {
        let csr = test_graph();
        // include invalid rows like a padded f1 frontier would
        let mut frontier = random_seeds(&csr, 400, 3);
        frontier[7] = -1;
        frontier[123] = -1;
        let serial = crate::sampler::sample_frontier(&csr, &frontier, 5, 99, 1);
        for threads in [1usize, 2, 3, 8, 16] {
            let par = ParallelSampler::new(threads)
                .sample_frontier(&csr, &frontier, 5, 99, 1);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn block2_bitwise_identical_across_thread_counts() {
        let csr = test_graph();
        let seeds = random_seeds(&csr, 256, 11);
        let serial = crate::sampler::build_block2(&csr, &seeds, 4, 3, 42);
        for threads in [1usize, 2, 8] {
            let par = ParallelSampler::new(threads)
                .build_block2(&csr, &seeds, 4, 3, 42);
            assert_eq!(par.f1, serial.f1, "f1 differs at threads={threads}");
            assert_eq!(par.s2, serial.s2, "s2 differs at threads={threads}");
            assert_eq!((par.batch, par.k1, par.k2),
                       (serial.batch, serial.k1, serial.k2));
        }
    }

    #[test]
    fn block1_bitwise_identical_across_thread_counts() {
        let csr = test_graph();
        let seeds = random_seeds(&csr, 256, 13);
        let serial = crate::sampler::build_block1(&csr, &seeds, 6, 7);
        for threads in [1usize, 2, 8] {
            let par = ParallelSampler::new(threads)
                .build_block1(&csr, &seeds, 6, 7);
            assert_eq!(par.f1, serial.f1, "threads={threads}");
            assert_eq!((par.batch, par.k), (serial.batch, serial.k));
        }
    }

    #[test]
    fn tiny_frontiers_take_the_serial_path() {
        let csr = test_graph();
        let seeds = random_seeds(&csr, 8, 5);
        let s = ParallelSampler::new(8);
        assert_eq!(s.workers_for(seeds.len()), 1);
        let serial = crate::sampler::build_block2(&csr, &seeds, 3, 2, 1);
        let par = s.build_block2(&csr, &seeds, 3, 2, 1);
        assert_eq!(par.f1, serial.f1);
        assert_eq!(par.s2, serial.s2);
    }

    #[test]
    fn zero_threads_means_auto() {
        assert!(ParallelSampler::new(0).threads() >= 1);
        assert_eq!(ParallelSampler::serial().threads(), 1);
    }

    /// Property: random frontiers, fanouts, and thread counts always match
    /// the serial sampler bitwise.
    #[test]
    fn prop_parallel_matches_serial() {
        let csr = test_graph();
        let mut r = SplitMix64::new(77);
        for _ in 0..25 {
            let n = 65 + r.next_below(400) as usize;
            let k = 1 + r.next_below(8) as usize;
            let base = r.next_u64();
            let frontier = random_seeds(&csr, n, r.next_u64());
            let serial =
                crate::sampler::sample_frontier(&csr, &frontier, k, base, 0);
            let threads = 1 + r.next_below(8) as usize;
            let par = ParallelSampler::new(threads)
                .sample_frontier(&csr, &frontier, k, base, 0);
            assert_eq!(par, serial, "n={n} k={k} threads={threads}");
        }
    }
}
