//! Uniform-without-replacement reservoir sampler — the paper's Alg. 1 line 6.
//!
//! Vitter's Algorithm R driven by the same counter RNG as everything else:
//! slot `i >= k` draws `j = rand(base, node, hop, i) % (i+1)` and replaces
//! `reservoir[j]` when `j < k`. Matches
//! `python/compile/kernels/ref.py::reservoir_sample` exactly.
//!
//! The benchmark grid uses the with-replacement counter-hash rule on *both*
//! variants (DESIGN.md §3 substitution); this implementation validates the
//! substitution and is exposed for users who need exact GraphSAGE
//! without-replacement semantics on the host path.

use crate::graph::Csr;
use crate::rng::rand_counter;

/// Sample up to `k` distinct neighbors of `node` into `out[..k]` (-1 padded).
pub fn reservoir_sample(csr: &Csr, node: i32, k: usize, base: u64, hop: u64,
                        out: &mut [i32]) {
    debug_assert!(out.len() >= k);
    if node < 0 {
        out[..k].fill(-1);
        return;
    }
    let deg = csr.degree(node) as usize;
    let ns = csr.neighbors(node);
    if deg == 0 {
        out[..k].fill(-1);
        return;
    }
    if deg <= k {
        out[..deg].copy_from_slice(ns);
        out[deg..k].fill(-1);
        return;
    }
    out[..k].copy_from_slice(&ns[..k]);
    for i in k..deg {
        let j = rand_counter(base, node as u64, hop, i as u64) % (i as u64 + 1);
        if (j as usize) < k {
            out[j as usize] = ns[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;

    fn star(center_deg: usize) -> Csr {
        let edges: Vec<(u32, u32)> =
            (1..=center_deg as u32).map(|i| (0, i)).collect();
        Csr::from_edges(center_deg + 1, &edges, 4 * center_deg, true).unwrap()
    }

    #[test]
    fn no_replacement() {
        let csr = star(50);
        let mut out = vec![0i32; 10];
        for seed in 0..20u64 {
            reservoir_sample(&csr, 0, 10, seed, 0, &mut out);
            let mut s = out.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 10, "duplicates with seed {seed}: {out:?}");
            for &v in &out {
                assert!(csr.neighbors(0).contains(&v));
            }
        }
    }

    #[test]
    fn take_all_and_padding() {
        let csr = star(3);
        let mut out = vec![0i32; 5];
        reservoir_sample(&csr, 0, 5, 1, 0, &mut out);
        assert_eq!(&out[..3], csr.neighbors(0));
        assert_eq!(&out[3..], &[-1, -1]);
    }

    #[test]
    fn deterministic() {
        let csr = star(40);
        let mut a = vec![0i32; 8];
        let mut b = vec![0i32; 8];
        reservoir_sample(&csr, 0, 8, 77, 1, &mut a);
        reservoir_sample(&csr, 0, 8, 77, 1, &mut b);
        assert_eq!(a, b);
        reservoir_sample(&csr, 0, 8, 78, 1, &mut b);
        assert_ne!(a, b);
    }

    /// Statistical uniformity: over many base seeds every neighbor of a
    /// degree-30 node should be selected roughly k/deg of the time.
    #[test]
    fn roughly_uniform_inclusion() {
        let csr = star(30);
        let k = 6;
        let trials = 3000u64;
        let mut counts = vec![0u32; 31];
        let mut out = vec![0i32; k];
        let mut r = SplitMix64::new(123);
        for _ in 0..trials {
            reservoir_sample(&csr, 0, k, r.next_u64(), 0, &mut out);
            for &v in &out {
                counts[v as usize] += 1;
            }
        }
        let expect = trials as f64 * k as f64 / 30.0;
        for v in 1..=30 {
            let c = counts[v] as f64;
            assert!(
                (c - expect).abs() < expect * 0.25,
                "neighbor {v}: {c} vs expected {expect}"
            );
        }
    }
}
